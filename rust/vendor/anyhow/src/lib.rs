//! Offline stand-in for the `anyhow` crate covering the subset this
//! workspace uses: `Error`, `Result`, the `Context` extension trait on
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters here:
//! * `Display` prints the outermost context; `{:#}` prints the whole
//!   chain joined with `: `; `Debug` prints a `Caused by:` listing.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (its `source()` chain is flattened into the context chain).

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error.  `chain[0]` is the outermost context, the
/// last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly
// like upstream anyhow: that is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("condition failed: ", ::std::stringify!($cond)),
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("opening manifest").unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let w: Option<u32> = Some(3);
        assert_eq!(w.with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn macros_compile_and_fire() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(0).is_err());
        assert!(format!("{:#}", f(-3).unwrap_err()).contains("negative input -3"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }
}
