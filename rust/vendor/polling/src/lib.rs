//! Offline stand-in for the `polling` crate: a minimal readiness
//! poller over **epoll** on Linux and **kqueue** on macOS, covering
//! exactly the surface the `watersic` reactor front door uses.
//!
//! Divergence from the real crate (kept deliberately small so the
//! path dependency can be re-pointed at crates.io when network access
//! exists): registrations here are **level-triggered and persistent**
//! — an interest stays armed until `modify`/`delete` — where the real
//! crate defaults to oneshot.  The reactor only re-arms on interest
//! *changes*, which is exactly the level-triggered contract.
//!
//! No `libc` crate exists offline; the raw syscall surface is declared
//! directly (std already links the platform C library).

use std::io;
use std::time::Duration;

#[cfg(unix)]
pub type RawFd = std::os::fd::RawFd;
#[cfg(not(unix))]
pub type RawFd = i64;

/// A readiness interest or readiness report for one registered fd,
/// identified by the caller-chosen `key`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub key: usize,
    pub readable: bool,
    pub writable: bool,
}

impl Event {
    pub fn readable(key: usize) -> Event {
        Event {
            key,
            readable: true,
            writable: false,
        }
    }

    pub fn writable(key: usize) -> Event {
        Event {
            key,
            readable: false,
            writable: true,
        }
    }

    pub fn all(key: usize) -> Event {
        Event {
            key,
            readable: true,
            writable: true,
        }
    }

    pub fn none(key: usize) -> Event {
        Event {
            key,
            readable: false,
            writable: false,
        }
    }
}

/// The OS readiness queue.  `add`/`modify`/`delete` manage registered
/// fds; `wait` blocks up to `timeout` and appends ready [`Event`]s.
pub struct Poller {
    sys: sys::Poller,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller {
            sys: sys::Poller::new()?,
        })
    }

    /// Register `fd` with the given interest (level-triggered).
    pub fn add(&self, fd: RawFd, interest: Event) -> io::Result<()> {
        self.sys.add(fd, interest)
    }

    /// Replace the interest of an already-registered `fd`.
    pub fn modify(&self, fd: RawFd, interest: Event) -> io::Result<()> {
        self.sys.modify(fd, interest)
    }

    /// Deregister `fd` entirely.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.sys.delete(fd)
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// expires (`None` blocks indefinitely), appending readiness
    /// events and returning how many were appended.
    pub fn wait(
        &self,
        events: &mut Vec<Event>,
        timeout: Option<Duration>,
    ) -> io::Result<usize> {
        self.sys.wait(events, timeout)
    }
}

#[cfg(target_os = "linux")]
mod sys {
    use super::{Event, RawFd};
    use std::io;
    use std::time::Duration;

    // Matching the kernel ABI: packed on x86-64 only (the kernel
    // struct is __attribute__((packed)) there; aarch64 and others use
    // natural alignment).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    const WAIT_CAP: usize = 64;

    pub struct Poller {
        epfd: i32,
    }

    fn mask(interest: Event) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    fn ctl(epfd: i32, op: i32, fd: RawFd, interest: Option<Event>) -> io::Result<()> {
        let mut ev = EpollEvent {
            events: interest.map(mask).unwrap_or(0),
            data: interest.map(|e| e.key as u64).unwrap_or(0),
        };
        // SAFETY: epfd is a live epoll fd owned by this Poller and ev
        // outlives the call; the kernel copies it before returning.
        let rc = unsafe { epoll_ctl(epfd, op, fd as i32, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        pub fn add(&self, fd: RawFd, interest: Event) -> io::Result<()> {
            ctl(self.epfd, EPOLL_CTL_ADD, fd, Some(interest))
        }

        pub fn modify(&self, fd: RawFd, interest: Event) -> io::Result<()> {
            ctl(self.epfd, EPOLL_CTL_MOD, fd, Some(interest))
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            ctl(self.epfd, EPOLL_CTL_DEL, fd, None)
        }

        pub fn wait(
            &self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            let ms: i32 = match timeout {
                None => -1,
                Some(d) => {
                    // round up so a 100µs timeout still sleeps
                    d.as_micros().div_ceil(1000).min(i32::MAX as u128) as i32
                }
            };
            let mut buf = [EpollEvent { events: 0, data: 0 }; WAIT_CAP];
            // SAFETY: buf is a live stack array of WAIT_CAP entries
            // and the kernel writes at most WAIT_CAP of them.
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), WAIT_CAP as i32, ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(err);
            }
            for e in buf.iter().take(n as usize) {
                // copy out of the (possibly packed) struct by value —
                // no references into unaligned fields
                let bits = { e.events };
                let data = { e.data };
                events.push(Event {
                    key: data as usize,
                    readable: bits & (EPOLLIN | EPOLLHUP | EPOLLERR | EPOLLRDHUP)
                        != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(n as usize)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: epfd is owned by this Poller and not used again.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(target_os = "macos")]
mod sys {
    use super::{Event, RawFd};
    use std::io;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct KEvent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: usize,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: isize,
        tv_nsec: isize,
    }

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const KEvent,
            nchanges: i32,
            eventlist: *mut KEvent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x1;
    const EV_DELETE: u16 = 0x2;
    const EV_ERROR: u16 = 0x4000;

    const WAIT_CAP: usize = 64;

    pub struct Poller {
        kq: i32,
    }

    fn change(kq: i32, fd: RawFd, filter: i16, flags: u16, key: usize) -> i32 {
        let ev = KEvent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: key,
        };
        // SAFETY: kq is a live kqueue fd owned by this Poller; ev
        // outlives the call and the kernel copies it.
        unsafe { kevent(kq, &ev, 1, std::ptr::null_mut(), 0, std::ptr::null()) }
    }

    fn apply(kq: i32, fd: RawFd, interest: Event) -> io::Result<()> {
        for (filter, on) in [
            (EVFILT_READ, interest.readable),
            (EVFILT_WRITE, interest.writable),
        ] {
            if on {
                if change(kq, fd, filter, EV_ADD, interest.key) < 0 {
                    return Err(io::Error::last_os_error());
                }
            } else {
                // removing a filter that was never armed is fine
                let _ = change(kq, fd, filter, EV_DELETE, interest.key);
            }
        }
        Ok(())
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers.
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { kq })
        }

        pub fn add(&self, fd: RawFd, interest: Event) -> io::Result<()> {
            apply(self.kq, fd, interest)
        }

        pub fn modify(&self, fd: RawFd, interest: Event) -> io::Result<()> {
            apply(self.kq, fd, interest)
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            let _ = change(self.kq, fd, EVFILT_READ, EV_DELETE, 0);
            let _ = change(self.kq, fd, EVFILT_WRITE, EV_DELETE, 0);
            Ok(())
        }

        pub fn wait(
            &self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            let ts;
            let ts_ptr = match timeout {
                None => std::ptr::null(),
                Some(d) => {
                    ts = Timespec {
                        tv_sec: d.as_secs().min(isize::MAX as u64) as isize,
                        tv_nsec: d.subsec_nanos() as isize,
                    };
                    &ts as *const Timespec
                }
            };
            let mut buf = [KEvent {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: 0,
            }; WAIT_CAP];
            // SAFETY: buf is a live stack array of WAIT_CAP entries
            // and the kernel writes at most WAIT_CAP of them; ts (when
            // non-null) outlives the call.
            let n = unsafe {
                kevent(
                    self.kq,
                    std::ptr::null(),
                    0,
                    buf.as_mut_ptr(),
                    WAIT_CAP as i32,
                    ts_ptr,
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(err);
            }
            let mut pushed = 0;
            for e in buf.iter().take(n as usize) {
                if e.flags & EV_ERROR != 0 {
                    continue;
                }
                events.push(Event {
                    key: e.udata,
                    readable: e.filter == EVFILT_READ,
                    writable: e.filter == EVFILT_WRITE,
                });
                pushed += 1;
            }
            Ok(pushed)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: kq is owned by this Poller and not used again.
            unsafe {
                close(self.kq);
            }
        }
    }
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
mod sys {
    use super::{Event, RawFd};
    use std::io;
    use std::time::Duration;

    /// Unsupported platform: construction fails cleanly and the caller
    /// (the watersic front door) falls back to its threaded path.
    pub struct Poller {
        _private: (),
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "polling shim: no epoll/kqueue backend on this platform",
            ))
        }

        pub fn add(&self, _fd: RawFd, _interest: Event) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn modify(&self, _fd: RawFd, _interest: Event) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn delete(&self, _fd: RawFd) -> io::Result<()> {
            unreachable!("Poller cannot be constructed on this platform")
        }

        pub fn wait(
            &self,
            _events: &mut Vec<Event>,
            _timeout: Option<Duration>,
        ) -> io::Result<usize> {
            unreachable!("Poller cannot be constructed on this platform")
        }
    }
}

#[cfg(all(test, any(target_os = "linux", target_os = "macos")))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn tcp_readiness_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let poller = Poller::new().unwrap();
        poller.add(listener.as_raw_fd(), Event::readable(7)).unwrap();

        let mut client = TcpStream::connect(addr).unwrap();
        let mut events = Vec::new();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.key == 7 && e.readable));

        let (mut peer, _) = listener.accept().unwrap();
        peer.set_nonblocking(true).unwrap();
        poller.add(peer.as_raw_fd(), Event::readable(9)).unwrap();
        client.write_all(b"hi").unwrap();
        events.clear();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.key == 9 && e.readable));
        let mut buf = [0u8; 2];
        peer.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");

        // write-interest on an idle socket reports writable
        poller.modify(peer.as_raw_fd(), Event::all(9)).unwrap();
        events.clear();
        poller
            .wait(&mut events, Some(Duration::from_secs(5)))
            .unwrap();
        assert!(events.iter().any(|e| e.key == 9 && e.writable));

        poller.delete(peer.as_raw_fd()).unwrap();
        events.clear();
        let n = poller
            .wait(&mut events, Some(Duration::from_millis(20)))
            .unwrap();
        assert_eq!(n, events.len());
        assert!(events.iter().all(|e| e.key != 9));
    }

    #[test]
    fn timeout_expires_with_no_events() {
        let poller = Poller::new().unwrap();
        let mut events = Vec::new();
        let t = std::time::Instant::now();
        let n = poller
            .wait(&mut events, Some(Duration::from_millis(10)))
            .unwrap();
        assert_eq!(n, 0);
        assert!(t.elapsed() >= Duration::from_millis(5));
    }
}
