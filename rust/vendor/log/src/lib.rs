//! Offline stand-in for the `log` facade crate: the `Log` trait, a
//! global logger slot, level filtering, and the five level macros.
//! Covers exactly the surface the `watersic` binary uses.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

pub struct Record<'a> {
    level: Level,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> Metadata {
        Metadata { level: self.level }
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger has already been installed")
    }
}

impl std::error::Error for SetLoggerError {}

// Global logger: a fat pointer cannot live in one AtomicPtr, so store
// the &'static dyn Log behind a leaked thin box.
static LOGGER: AtomicPtr<&'static dyn Log> = AtomicPtr::new(std::ptr::null_mut());
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let boxed: *mut &'static dyn Log = Box::into_raw(Box::new(logger));
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        boxed,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // SAFETY: `boxed` came from Box::into_raw above and was
            // never published.
            drop(unsafe { Box::from_raw(boxed) });
            Err(SetLoggerError(()))
        }
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not part of the public API.
#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::SeqCst) {
        return;
    }
    let ptr = LOGGER.load(Ordering::SeqCst);
    if ptr.is_null() {
        return;
    }
    // SAFETY: once published the box is never freed (set_logger only
    // installs into an empty slot).
    let logger: &'static dyn Log = unsafe { *ptr };
    let record = Record { level, args };
    if logger.enabled(&record.metadata()) {
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__private_log($crate::Level::Error, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__private_log($crate::Level::Warn, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__private_log($crate::Level::Info, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__private_log($crate::Level::Debug, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__private_log($crate::Level::Trace, ::std::format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            let _ = format!("{} {}", record.level(), record.args());
            HITS.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filter_and_dispatch() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Warn);
        crate::warn!("visible {}", 1);
        crate::debug!("filtered");
        assert_eq!(HITS.load(Ordering::SeqCst), 1);
        assert_eq!(max_level(), LevelFilter::Warn);
        // second install fails
        assert!(set_logger(&C).is_err());
    }
}
