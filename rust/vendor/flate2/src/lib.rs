//! Offline stand-in for the `flate2` crate (write-side Zlib encoder
//! only).  Output is the [`microcomp`] order-0 Huffman stream, not RFC
//! 1950 zlib — round-trip exact and near order-0 entropy, which is all
//! the workspace's codec-comparison tables need from it offline.

/// Compression level (accepted for API compatibility, ignored).
#[derive(Clone, Copy, Debug)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

pub mod write {
    use std::io::{self, Write};

    /// Buffers all writes, compresses on [`finish`](ZlibEncoder::finish).
    pub struct ZlibEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> ZlibEncoder<W> {
        pub fn new(inner: W, _level: super::Compression) -> ZlibEncoder<W> {
            ZlibEncoder {
                inner,
                buf: Vec::new(),
            }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let comp = microcomp::compress(&self.buf);
            self.inner.write_all(&comp)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for ZlibEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    #[test]
    fn encoder_compresses_through_finish() {
        let mut enc = super::write::ZlibEncoder::new(Vec::new(), super::Compression::best());
        enc.write_all(&vec![42u8; 4096]).unwrap();
        let out = enc.finish().unwrap();
        assert!(!out.is_empty() && out.len() < 4096);
        assert_eq!(microcomp::decompress(&out).unwrap(), vec![42u8; 4096]);
    }
}
