//! A tiny, dependency-free, byte-oriented entropy codec: order-0
//! canonical Huffman with an explicit 256-entry code-length header.
//! It backs the offline stand-ins for `zstd` and `flate2`, giving the
//! workspace *real* (near-entropy, round-trip exact) general-purpose
//! compression without network access.  It is NOT the zstd/DEFLATE wire
//! format.
//!
//! Stream layout:
//!   [0]      format tag (1)
//!   [1..9]   u64 LE uncompressed length
//!   [9..265] 256 code lengths (u8; 0 = symbol absent)
//!   [265..]  MSB-first bit-packed payload

const TAG: u8 = 1;

/// Compress `src`; always succeeds.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let mut freq = [0u64; 256];
    for &b in src {
        freq[b as usize] += 1;
    }
    let mut lengths = huffman_lengths(&freq);
    // Bit-packer safety: keep codes ≤ 32 bits.  Depths past 32 need
    // fibonacci-like frequency profiles over terabytes of input; if one
    // ever shows up, fall back to a flat 8-bit prefix code (Kraft ≤ 1
    // for ≤ 256 symbols, so it stays a valid canonical code).
    if lengths.iter().any(|&l| l > 32) {
        for l in lengths.iter_mut() {
            if *l > 0 {
                *l = 8;
            }
        }
    }
    let codes = canonical_codes(&lengths);

    let mut out = Vec::with_capacity(265 + src.len() / 2);
    out.push(TAG);
    out.extend_from_slice(&(src.len() as u64).to_le_bytes());
    out.extend_from_slice(&lengths);

    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for &b in src {
        let (code, len) = codes[b as usize];
        debug_assert!(len > 0);
        acc = (acc << len) | code as u64;
        nbits += len;
        while nbits >= 8 {
            nbits -= 8;
            out.push((acc >> nbits) as u8);
        }
    }
    if nbits > 0 {
        out.push((acc << (8 - nbits)) as u8);
    }
    out
}

/// Decompress a [`compress`] stream.
pub fn decompress(src: &[u8]) -> Result<Vec<u8>, String> {
    if src.len() < 265 || src[0] != TAG {
        return Err("microcomp: bad header".to_string());
    }
    let n = u64::from_le_bytes(src[1..9].try_into().unwrap()) as usize;
    let mut lengths = [0u8; 256];
    lengths.copy_from_slice(&src[9..265]);
    if n == 0 {
        return Ok(Vec::new());
    }
    if lengths.iter().any(|&l| l > 32) {
        return Err("microcomp: invalid code length".to_string());
    }

    // Canonical decode: first-code arithmetic per length.
    let mut first_code = [0u32; 64]; // first canonical code of each length
    let mut count = [0u32; 64];
    for &l in lengths.iter() {
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut code: u32 = 0;
    for l in 1..64usize {
        code = (code + count[l - 1]) << 1;
        first_code[l] = code;
    }
    // symbols of each length in symbol order (canonical assignment order)
    let mut syms_by_len: Vec<Vec<u8>> = vec![Vec::new(); 64];
    for (sym, &l) in lengths.iter().enumerate() {
        if l > 0 {
            syms_by_len[l as usize].push(sym as u8);
        }
    }

    let payload = &src[265..];
    let mut out = Vec::with_capacity(n);
    let mut bitpos: usize = 0;
    let total_bits = payload.len() * 8;
    while out.len() < n {
        let mut code: u32 = 0;
        let mut len: usize = 0;
        loop {
            if bitpos >= total_bits {
                return Err("microcomp: truncated stream".to_string());
            }
            let bit = (payload[bitpos / 8] >> (7 - (bitpos % 8))) & 1;
            bitpos += 1;
            code = (code << 1) | bit as u32;
            len += 1;
            if len >= 64 {
                return Err("microcomp: invalid code".to_string());
            }
            let cnt = count[len];
            if cnt > 0 {
                let first = first_code[len];
                if code >= first && code < first + cnt {
                    out.push(syms_by_len[len][(code - first) as usize]);
                    break;
                }
            }
        }
    }
    Ok(out)
}

/// Huffman code lengths from byte frequencies (package-free pairing via
/// a simple two-queue merge on sorted leaves).
fn huffman_lengths(freq: &[u64; 256]) -> [u8; 256] {
    let mut lengths = [0u8; 256];
    let present: Vec<usize> = (0..256).filter(|&i| freq[i] > 0).collect();
    match present.len() {
        0 => return lengths,
        1 => {
            lengths[present[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // nodes: (weight, id); leaves have id < 256, internal nodes ≥ 256
    #[derive(Clone)]
    struct Node {
        weight: u64,
        left: Option<usize>,  // index into `nodes`
        right: Option<usize>, // index into `nodes`
        symbol: Option<usize>,
    }
    let mut nodes: Vec<Node> = present
        .iter()
        .map(|&s| Node {
            weight: freq[s],
            left: None,
            right: None,
            symbol: Some(s),
        })
        .collect();
    // simple O(k²) merge — alphabet ≤ 256, negligible
    let mut live: Vec<usize> = (0..nodes.len()).collect();
    while live.len() > 1 {
        // find two smallest weights
        let mut a = 0usize;
        let mut b = 1usize;
        if nodes[live[b]].weight < nodes[live[a]].weight {
            std::mem::swap(&mut a, &mut b);
        }
        for i in 2..live.len() {
            let w = nodes[live[i]].weight;
            if w < nodes[live[a]].weight {
                b = a;
                a = i;
            } else if w < nodes[live[b]].weight {
                b = i;
            }
        }
        let (ia, ib) = (live[a], live[b]);
        let merged = Node {
            weight: nodes[ia].weight + nodes[ib].weight,
            left: Some(ia),
            right: Some(ib),
            symbol: None,
        };
        nodes.push(merged);
        let mi = nodes.len() - 1;
        // remove the two (larger index first to keep positions valid)
        let (hi, lo) = if a > b { (a, b) } else { (b, a) };
        live.remove(hi);
        live.remove(lo);
        live.push(mi);
    }

    // depth-first assign lengths
    let mut stack: Vec<(usize, u8)> = vec![(live[0], 0)];
    while let Some((idx, depth)) = stack.pop() {
        let node = nodes[idx].clone();
        if let Some(sym) = node.symbol {
            lengths[sym] = depth.max(1);
        } else {
            if let Some(l) = node.left {
                stack.push((l, depth + 1));
            }
            if let Some(r) = node.right {
                stack.push((r, depth + 1));
            }
        }
    }
    lengths
}

/// Canonical codes from lengths: (code, len) per symbol.
fn canonical_codes(lengths: &[u8; 256]) -> [(u32, u32); 256] {
    let mut codes = [(0u32, 0u32); 256];
    let mut count = [0u32; 64];
    for &l in lengths.iter() {
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut next = [0u32; 64];
    let mut code: u32 = 0;
    for l in 1..64usize {
        code = (code + count[l - 1]) << 1;
        next[l] = code;
    }
    for sym in 0..256usize {
        let l = lengths[sym] as usize;
        if l > 0 {
            codes[sym] = (next[l], l as u32);
            next[l] += 1;
        }
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn roundtrips() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"aaaaaaaaaaaaaaaa");
        roundtrip(b"the quick brown fox jumps over the lazy dog");
        let all: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&all);
        // skewed pseudo-random
        let mut x = 12345u64;
        let skew: Vec<u8> = (0..20_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((x >> 60) as u8).min(4)
            })
            .collect();
        roundtrip(&skew);
    }

    #[test]
    fn compresses_skewed_data() {
        let data = vec![7u8; 10_000];
        let c = compress(&data);
        assert!(c.len() < 2000, "constant stream should compress: {}", c.len());
    }

    #[test]
    fn rejects_garbage() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[9; 300]).is_err());
    }
}
