//! Offline stub of the xla-rs / PJRT bindings.
//!
//! The coordinator treats the PJRT engine as optional: `Engine::new`
//! calls [`PjRtClient::cpu`], and on error every caller falls back to
//! the Rust-native oracle (`quant::zsic`, `model::transformer`).  This
//! stub makes that construction fail cleanly with a descriptive error,
//! so the whole crate builds and runs with no libxla on the machine.
//! Every post-construction method is unreachable in practice (no client
//! can exist) but is implemented to return errors, not panic.

use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(String);

impl XlaError {
    fn new(msg: &str) -> XlaError {
        XlaError(msg.to_string())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str =
    "xla stub: PJRT runtime not built into this binary (offline build; \
     link the real xla bindings to enable artifacts)";

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        Err(XlaError::new(UNAVAILABLE))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT runtime not built"));
    }

    #[test]
    fn literal_builders_are_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[1, 2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }
}
