//! Offline stand-in for the `zstd` crate (bulk API only).  Compression
//! is a real, round-trip-exact order-0 canonical-Huffman byte codec
//! ([`microcomp`]), which lands near the order-0 entropy on the i.i.d.
//! integer-code streams this workspace feeds it — but it is NOT the
//! zstd wire format and has no LZ77 matching.  Numbers reported through
//! it are an order-0 upper bound on what real zstd would achieve.

pub mod bulk {
    use std::io;

    /// Compress `source` (the level is accepted for API compatibility
    /// and ignored — the backing codec has a single operating point).
    pub fn compress(source: &[u8], _level: i32) -> io::Result<Vec<u8>> {
        Ok(microcomp::compress(source))
    }

    /// Decompress a [`compress`] stream; `capacity` is an upper bound
    /// hint in the real API and is only sanity-checked here.
    pub fn decompress(source: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        let out = microcomp::decompress(source)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        if out.len() > capacity {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "decompressed size exceeds declared capacity",
            ));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bulk_roundtrip() {
        let data: Vec<u8> = (0..5000u32).map(|i| (i % 7) as u8).collect();
        let c = super::bulk::compress(&data, 19).unwrap();
        assert!(c.len() < data.len());
        let d = super::bulk::decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn capacity_enforced() {
        let c = super::bulk::compress(&[1u8; 100], 3).unwrap();
        assert!(super::bulk::decompress(&c, 10).is_err());
    }
}
