//! Diagnostic experiments: rescaler statistics (Fig. 4), per-column
//! entropy distributions (Fig. 5), codec-vs-entropy rates (Table 6),
//! weight Gaussianity (Fig. 11), component ablations (Figs. 6–10), and
//! adaptive-mixing coefficients (Tables 3–4).

use anyhow::Result;

use crate::coordinator::{quantize_model, Algo, PipelineOpts};
use crate::entropy::external::{deflate_bpp, zstd_bpp};
use crate::entropy::{Codec, column_entropies, entropy_bits};
use crate::eval::gaussianity_report;
use crate::linalg::stats::median;
use crate::util::json::{obj, Json};

use super::llm::pipeline_opts;
use super::Ctx;

/// Fig. 4 analog: distribution of the diagonal rescalers T and Γ vs rate.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let rates = if ctx.fast {
        vec![1.0, 4.0]
    } else {
        vec![1.0, 2.0, 3.0, 4.0]
    };
    println!("Fig. 4 analog — rescaler statistics vs rate (picollama_s)");
    println!(
        "{:>5} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}",
        "Rate", "Γ p10", "Γ med", "Γ p90", "T p10", "T med", "T p90"
    );
    println!("{}", "-".repeat(64));
    let mut records = Vec::new();
    for &rate in &rates {
        let o = pipeline_opts(ctx, Algo::WaterSic, rate, false);
        let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
        let mut gammas = Vec::new();
        let mut ts = Vec::new();
        for q in qm.quants.values() {
            // live columns only (dead ones have γ = 0 by construction)
            for j in 0..q.n {
                if !q.dead_cols.contains(&j) {
                    gammas.push(q.gammas[j]);
                }
            }
            ts.extend_from_slice(&q.t);
        }
        let pct = |v: &mut Vec<f64>, q: f64| {
            v.sort_by(|a, b| a.total_cmp(b));
            v[((v.len() - 1) as f64 * q) as usize]
        };
        let (g10, g50, g90) = (pct(&mut gammas, 0.1), pct(&mut gammas, 0.5), pct(&mut gammas, 0.9));
        let (t10, t50, t90) = (pct(&mut ts, 0.1), pct(&mut ts, 0.5), pct(&mut ts, 0.9));
        println!(
            "{rate:>5.1} | {g10:>8.3} {g50:>8.3} {g90:>8.3} | {t10:>8.3} {t50:>8.3} {t90:>8.3}"
        );
        records.push(obj(vec![
            ("rate", Json::Num(rate)),
            ("gamma_med", Json::Num(g50)),
            ("t_med", Json::Num(t50)),
        ]));
    }
    println!("(LMMSE shrinkage: Γ well below 1 at 1 bit, → 1 by 4 bits)");
    ctx.save_results("fig4", Json::Arr(records));
    Ok(())
}

/// Fig. 5 analog: per-in-channel entropy distribution.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let rate = 2.125;
    let o = pipeline_opts(ctx, Algo::WaterSic, rate, false);
    let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
    println!("Fig. 5 analog — per-column entropy distribution at {rate} bits");
    let mut all: Vec<f64> = Vec::new();
    for (name, q) in &qm.quants {
        let ce = q.column_entropies();
        let live: Vec<f64> = ce
            .iter()
            .enumerate()
            .filter(|(j, _)| !q.dead_cols.contains(j))
            .map(|(_, &e)| e)
            .collect();
        let mx = live.iter().cloned().fold(0.0, f64::max);
        let avg = live.iter().sum::<f64>() / live.len() as f64;
        println!("  {name:<22} max {mx:5.2}  avg {avg:5.2}  (n={})", live.len());
        all.extend(live);
    }
    // histogram over all layers
    println!("\nAll-column histogram (bits):");
    let buckets = 12usize;
    let hi = all.iter().cloned().fold(0.0, f64::max).max(1e-9);
    let mut hist = vec![0usize; buckets];
    for &e in &all {
        let b = ((e / hi) * buckets as f64) as usize;
        hist[b.min(buckets - 1)] += 1;
    }
    let peak = *hist.iter().max().unwrap();
    for (b, &c) in hist.iter().enumerate() {
        let bar = "#".repeat((c * 48).div_ceil(peak.max(1)));
        println!(
            "  [{:4.2}–{:4.2}) {:>5}  {bar}",
            hi * b as f64 / buckets as f64,
            hi * (b + 1) as f64 / buckets as f64,
            c
        );
    }
    let spread = {
        let mut v = all.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        v[(v.len() * 9) / 10] - v[v.len() / 10]
    };
    println!(
        "\np90−p10 column-rate spread: {spread:.2} bits — the unequal \
         per-channel allocation that uniform-rate methods cannot express."
    );
    ctx.save_results(
        "fig5",
        obj(vec![
            ("rate", Json::Num(rate)),
            ("spread_p90_p10", Json::Num(spread)),
            ("n_columns", Json::Num(all.len() as f64)),
        ]),
    );
    Ok(())
}

/// Table 6 analog: entropy estimate vs achieved codec bits/parameter.
pub fn table6(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let o = pipeline_opts(ctx, Algo::WaterSic, 2.0, false);
    let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
    println!("Table 6 analog — entropy vs codec bpp (target 2.0 bits)");
    println!(
        "{:<22} {:>8} {:>9} {:>9} {:>8} {:>9} {:>8} {:>8}",
        "Matrix", "entropy", "max(col)", "avg(col)", "zstd", "deflate", "huff", "rANS"
    );
    println!("{}", "-".repeat(88));
    let mut records = Vec::new();
    for (name, q) in &qm.quants {
        let ent = entropy_bits(&q.z);
        let ce = column_entropies(&q.z, q.a, q.n);
        let mx = ce.iter().cloned().fold(0.0, f64::max);
        let avg = ce.iter().sum::<f64>() / ce.len() as f64;
        let z = zstd_bpp(&q.z, q.a, q.n);
        let d = deflate_bpp(&q.z, q.a, q.n);
        let h = crate::entropy::huffman::Huffman.rate(&q.z);
        let r = crate::entropy::rans::Rans.rate(&q.z);
        println!(
            "{name:<22} {ent:>8.3} {mx:>9.3} {avg:>9.3} {z:>8.3} {d:>9.3} {h:>8.3} {r:>8.3}"
        );
        records.push(obj(vec![
            ("matrix", Json::Str(name.clone())),
            ("entropy", Json::Num(ent)),
            ("zstd_bpp", Json::Num(z)),
            ("deflate_bpp", Json::Num(d)),
            ("huffman_bpp", Json::Num(h)),
            ("rans_bpp", Json::Num(r)),
        ]));
    }
    println!("(codecs should land within a few tenths of a bit of entropy)");
    ctx.save_results("table6", Json::Arr(records));
    Ok(())
}

/// Fig. 11 analog: Gaussian vs Laplace fits of the trained weights.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    println!("Fig. 11 analog — KS distance to best-fit Gaussian/Laplace");
    let mut records = Vec::new();
    for model in ["picollama_s", "picollama_m"] {
        let (cfg, w) = ctx.load_model(model)?;
        println!("\n{model}:");
        println!(
            "  {:<6} {:>10} {:>10}  {}",
            "type", "KS Gauss", "KS Laplace", "Gaussian preferred?"
        );
        for (ty, kg, kl, pref) in gaussianity_report(&cfg, &w) {
            println!(
                "  {:<6} {:>10.4} {:>10.4}  {}",
                ty,
                kg,
                kl,
                if pref { "yes" } else { "no" }
            );
            records.push(obj(vec![
                ("model", Json::Str(model.to_string())),
                ("type", Json::Str(ty)),
                ("ks_gauss", Json::Num(kg)),
                ("ks_laplace", Json::Num(kl)),
            ]));
        }
    }
    ctx.save_results("fig11", Json::Arr(records));
    Ok(())
}

/// Figs. 6–10 analog: component ablation via input relative MSE.
pub fn ablate(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let rate = if ctx.fast { 3.0 } else { 4.0 };
    println!("Figs. 6–10 analog — input relative MSE per group at {rate} bits");

    let variants: Vec<(&str, Box<dyn Fn(&mut PipelineOpts)>)> = vec![
        (
            "base",
            Box::new(|o: &mut PipelineOpts| {
                o.drift = false;
                o.residual = false;
                o.attn_weighted = false;
            }),
        ),
        (
            "+residual",
            Box::new(|o: &mut PipelineOpts| {
                o.drift = false;
                o.residual = true;
                o.attn_weighted = false;
            }),
        ),
        (
            "+qronos",
            Box::new(|o: &mut PipelineOpts| {
                o.drift = true;
                o.residual = true;
                o.attn_weighted = false;
            }),
        ),
        (
            "+attn-weight",
            Box::new(|o: &mut PipelineOpts| {
                o.drift = true;
                o.residual = true;
                o.attn_weighted = true;
            }),
        ),
        (
            "full(+mixing)",
            Box::new(|o: &mut PipelineOpts| {
                o.drift = true;
                o.residual = true;
                o.attn_weighted = true;
                o.mixing = true;
                o.mixing_iters = 4;
            }),
        ),
    ];

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut groups: Vec<String> = Vec::new();
    for (label, tweak) in &variants {
        let mut o = pipeline_opts(ctx, Algo::WaterSic, rate, false);
        tweak(&mut o);
        let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
        if groups.is_empty() {
            groups = qm.report.input_rel_mse.iter().map(|g| g.0.clone()).collect();
        }
        rows.push((
            label.to_string(),
            qm.report.input_rel_mse.iter().map(|g| g.1).collect(),
        ));
    }
    print!("{:<22}", "group");
    for (label, _) in &rows {
        print!(" {label:>14}");
    }
    println!();
    println!("{}", "-".repeat(22 + 15 * rows.len()));
    let mut records = Vec::new();
    for (gi, group) in groups.iter().enumerate() {
        print!("{group:<22}");
        for (label, vals) in &rows {
            print!(" {:>14.3e}", vals[gi]);
            records.push(obj(vec![
                ("group", Json::Str(group.clone())),
                ("variant", Json::Str(label.clone())),
                ("rel_mse", Json::Num(vals[gi])),
            ]));
        }
        println!();
    }
    // verdict: full ≤ base on average
    let avg = |vals: &[f64]| vals.iter().sum::<f64>() / vals.len() as f64;
    let base_avg = avg(&rows[0].1);
    let full_avg = avg(&rows.last().unwrap().1);
    println!(
        "\nmean rel MSE: base {base_avg:.3e} → full {full_avg:.3e}  ({})",
        if full_avg <= base_avg { "improved ✓" } else { "regressed ✗" }
    );
    ctx.save_results("ablate", Json::Arr(records));
    Ok(())
}

/// Tables 3–4 analog: optimal mixing coefficients per layer and rate.
pub fn mixing(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let rates = if ctx.fast { vec![2.125] } else { vec![2.125, 3.125, 4.125] };
    println!("Tables 3–4 analog — optimal (ε_qr, ε_aw) per layer");
    println!(
        "{:>6} {:>6} {:>8} {:>8}",
        "layer", "rate", "ε_qr*", "ε_aw*"
    );
    println!("{}", "-".repeat(32));
    let mut records = Vec::new();
    for &rate in &rates {
        let mut o = pipeline_opts(ctx, Algo::WaterSic, rate, false);
        o.mixing = true;
        o.mixing_iters = if ctx.fast { 4 } else { 8 };
        let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
        for (li, eqr, eaw) in &qm.report.mixing {
            println!("{li:>6} {rate:>6.3} {eqr:>8.4} {eaw:>8.4}");
            records.push(obj(vec![
                ("layer", Json::Num(*li as f64)),
                ("rate", Json::Num(rate)),
                ("eps_qr", Json::Num(*eqr)),
                ("eps_aw", Json::Num(*eaw)),
            ]));
        }
    }
    ctx.save_results("mixing", Json::Arr(records));
    Ok(())
}

pub fn _median_hint(xs: &[f64]) -> f64 {
    median(xs)
}
