//! LLM-scale experiments (picollama substitution for Llama/Qwen):
//! rate–perplexity tables and figures, calibration/finetuning-set
//! transfer, KL curves, probe-suite accuracy.

use anyhow::Result;

use crate::calib::corpus::Corpus;
use crate::coordinator::container::Container;
use crate::coordinator::{quantize_model, Algo, PipelineOpts, QuantizedModel};
use crate::eval;
use crate::ft::FtOpts;
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::util::json::{obj, Json};

use super::Ctx;

pub(crate) struct RunOut {
    pub qm: QuantizedModel,
    pub ppl_wiki: f64,
    pub ppl_web: f64,
    pub avg_rate: f64,
}

fn eval_count(ctx: &Ctx) -> usize {
    if ctx.fast {
        16
    } else {
        48
    }
}

pub fn pipeline_opts(ctx: &Ctx, algo: Algo, rate: f64, ft: bool) -> PipelineOpts {
    let mut o = match algo {
        Algo::WaterSic => PipelineOpts::watersic(rate),
        a => PipelineOpts::baseline(a, rate),
    };
    if ctx.fast {
        o.calib_windows = 8;
        o.calib_batch = 4;
        o.subsample_rows = 32;
    }
    if ft {
        o.finetune = Some(FtOpts {
            steps: if ctx.fast { 10 } else { 24 },
            peak_lr: 5e-3,
            min_lr: 1e-4,
        });
    }
    o
}

pub(crate) fn run_config(
    ctx: &Ctx,
    cfg: &ModelConfig,
    teacher: &Weights,
    calib_corpus: &Corpus,
    wiki: &Corpus,
    web: &Corpus,
    opts: &PipelineOpts,
) -> Result<RunOut> {
    let qm = quantize_model(cfg, teacher, calib_corpus, opts, ctx.engine.as_ref())?;
    let n_eval = eval_count(ctx);
    let wiki_windows = wiki.eval_windows(n_eval, cfg.ctx, 1234);
    let web_windows = web.eval_windows(n_eval, cfg.ctx, 1234);
    let ppl = |windows: &[(Vec<i32>, Vec<i32>)]| -> f64 {
        if let Some(engine) = &ctx.engine {
            if let Ok(p) =
                eval::perplexity_runtime(engine, cfg, &qm.student, windows, 8)
            {
                return p;
            }
        }
        eval::perplexity_native(cfg, &qm.student, windows)
    };
    let ppl_wiki = ppl(&wiki_windows);
    let ppl_web = ppl(&web_windows);
    let avg_rate = qm.report.avg_rate;
    Ok(RunOut {
        qm,
        ppl_wiki,
        ppl_web,
        avg_rate,
    })
}

fn rate_grid(ctx: &Ctx, full: &[f64], fast: &[f64]) -> Vec<f64> {
    if ctx.fast {
        fast.to_vec()
    } else {
        full.to_vec()
    }
}

/// Table 1 / Fig. 2 analog: rate–PPL frontier on picollama_s across all
/// algorithms.
pub fn table1(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(
        ctx,
        &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0],
        &[1.5, 2.5, 3.5],
    );
    println!(
        "Table 1 analog — {} (BF16 wiki PPL {:.3})",
        cfg.name, cfg.bf16_ppl_wiki
    );
    println!(
        "{:<16} {:>9} {:>12} {:>12}",
        "Method", "Avg. bits", "wiki PPL ↓", "web PPL ↓"
    );
    println!("{}", "-".repeat(54));
    let mut records = Vec::new();
    for &rate in &rates {
        let mut runs: Vec<(String, RunOut)> = Vec::new();
        for (label, algo, ft) in [
            ("WaterSIC-FT", Algo::WaterSic, true),
            ("WaterSIC", Algo::WaterSic, false),
            ("Huffman-GPTQ", Algo::HuffGptq, false),
            ("Huffman-RTN", Algo::HuffRtn, false),
        ] {
            let o = pipeline_opts(ctx, algo, rate, ft);
            runs.push((
                label.to_string(),
                run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?,
            ));
        }
        // log-cardinality baselines at the nearest integer width
        if (rate - rate.round()).abs() < 1e-9 && rate >= 2.0 {
            let bits = rate.round() as u32;
            let o = pipeline_opts(ctx, Algo::Rtn { bits }, rate, false);
            runs.push((
                format!("RTN (w{bits})"),
                run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?,
            ));
            let maxq = (1i32 << (bits - 1)) - 1;
            let o = pipeline_opts(ctx, Algo::Gptq { maxq }, rate, false);
            runs.push((
                format!("GPTQ (w{bits})"),
                run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?,
            ));
        }
        for (label, r) in &runs {
            println!(
                "{:<16} {:>9.2} {:>12.3} {:>12.3}",
                label, r.avg_rate, r.ppl_wiki, r.ppl_web
            );
            records.push(obj(vec![
                ("method", Json::Str(label.clone())),
                ("target_rate", Json::Num(rate)),
                ("avg_rate", Json::Num(r.avg_rate)),
                ("ppl_wiki", Json::Num(r.ppl_wiki)),
                ("ppl_web", Json::Num(r.ppl_web)),
            ]));
        }
        println!();
    }
    ctx.save_results("table1", Json::Arr(records));
    Ok(())
}

/// Table 2 analog on picollama_m at the paper's fractional rates.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_m")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(ctx, &[2.125, 2.625, 3.125, 3.625, 4.125], &[2.125, 3.125]);
    println!(
        "Table 2 analog — {} (BF16 wiki PPL {:.3})",
        cfg.name, cfg.bf16_ppl_wiki
    );
    print!("{:<16}", "Method (bits)");
    for r in &rates {
        print!(" {r:>8.3}");
    }
    println!();
    println!("{}", "-".repeat(16 + 9 * rates.len()));
    let mut records = Vec::new();
    for (label, algo, ft) in [
        ("Huffman-GPTQ", Algo::HuffGptq, false),
        ("GPTQ", Algo::Gptq { maxq: 3 }, false),
        ("Huffman-RTN", Algo::HuffRtn, false),
        ("RTN", Algo::Rtn { bits: 2 }, false),
        ("WaterSIC", Algo::WaterSic, false),
        ("WaterSIC-FT", Algo::WaterSic, true),
    ] {
        print!("{label:<16}");
        for &rate in &rates {
            // integer-grid baselines track the rate via their bit width
            let algo = match algo {
                Algo::Rtn { .. } => Algo::Rtn {
                    bits: rate.round().max(2.0) as u32,
                },
                Algo::Gptq { .. } => Algo::Gptq {
                    maxq: ((1i32 << (rate.round().max(2.0) as u32 - 1)) - 1).max(1),
                },
                a => a,
            };
            let o = pipeline_opts(ctx, algo, rate, ft);
            let r = run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?;
            print!(" {:>8.3}", r.ppl_wiki);
            records.push(obj(vec![
                ("method", Json::Str(label.to_string())),
                ("rate", Json::Num(rate)),
                ("avg_rate", Json::Num(r.avg_rate)),
                ("ppl_wiki", Json::Num(r.ppl_wiki)),
            ]));
            // keep stdout flowing for long runs
            use std::io::Write;
            std::io::stdout().flush().ok();
        }
        println!();
    }
    ctx.save_results("table2", Json::Arr(records));
    Ok(())
}

/// Fig. 1 analog: BPB vs measured compressed size across both models.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(ctx, &[1.0, 1.5, 2.0, 3.0, 4.0], &[1.5, 3.0]);
    println!("Fig. 1 analog — BPB vs compressed size (WaterSIC)");
    println!(
        "{:<14} {:>6} {:>12} {:>10} {:>10}",
        "model", "rate", "size (KiB)", "wiki BPB", "web BPB"
    );
    println!("{}", "-".repeat(56));
    let mut records = Vec::new();
    for model in ["picollama_s", "picollama_m"] {
        let (cfg, teacher) = ctx.load_model(model)?;
        for &rate in &rates {
            let o = pipeline_opts(ctx, Algo::WaterSic, rate, false);
            let r = run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?;
            let container =
                Container::new(&cfg.name, r.qm.quants.clone());
            // measured container + BF16 residual params (embeds, norms)
            let resid_bytes =
                2 * (cfg.n_params - cfg.quantizable_params());
            let kib =
                (container.size_bytes() + resid_bytes) as f64 / 1024.0;
            let bpb_w = eval::bits_per_byte(r.ppl_wiki);
            let bpb_c = eval::bits_per_byte(r.ppl_web);
            println!(
                "{:<14} {:>6.2} {:>12.1} {:>10.3} {:>10.3}",
                model, r.avg_rate, kib, bpb_w, bpb_c
            );
            records.push(obj(vec![
                ("model", Json::Str(model.to_string())),
                ("rate", Json::Num(r.avg_rate)),
                ("size_kib", Json::Num(kib)),
                ("bpb_wiki", Json::Num(bpb_w)),
                ("bpb_web", Json::Num(bpb_c)),
            ]));
        }
    }
    ctx.save_results("fig1", Json::Arr(records));
    Ok(())
}

/// Table 7 analog: in-domain (wiki) and off-domain (web ≙ C4) PPL for
/// WaterSIC and WaterSIC-FT across rates.
pub fn table7(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(ctx, &[1.0, 1.5, 2.0, 2.5, 3.0, 4.0], &[1.5, 3.0]);
    println!("Table 7 analog — {} (calibrated on wiki)", cfg.name);
    println!(
        "{:>5} | {:>10} {:>10} | {:>10} {:>10}",
        "Rate", "WS wiki", "WS web", "FT wiki", "FT web"
    );
    println!("{}", "-".repeat(56));
    let mut records = Vec::new();
    for &rate in &rates {
        let base = run_config(
            ctx, &cfg, &teacher, &wiki, &wiki, &web,
            &pipeline_opts(ctx, Algo::WaterSic, rate, false),
        )?;
        let ft = run_config(
            ctx, &cfg, &teacher, &wiki, &wiki, &web,
            &pipeline_opts(ctx, Algo::WaterSic, rate, true),
        )?;
        println!(
            "{:>5.2} | {:>10.3} {:>10.3} | {:>10.3} {:>10.3}",
            rate, base.ppl_wiki, base.ppl_web, ft.ppl_wiki, ft.ppl_web
        );
        records.push(obj(vec![
            ("rate", Json::Num(rate)),
            ("ws_wiki", Json::Num(base.ppl_wiki)),
            ("ws_web", Json::Num(base.ppl_web)),
            ("ft_wiki", Json::Num(ft.ppl_wiki)),
            ("ft_web", Json::Num(ft.ppl_web)),
        ]));
    }
    println!(
        "(off-domain gap should widen at low rates; FT narrows in-domain first)"
    );
    ctx.save_results("table7", Json::Arr(records));
    Ok(())
}

/// Table 15 analog: calibration set × finetuning set at a low rate.
pub fn table15(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rate = 2.0;
    println!("Table 15 analog — {} at {rate} bits", cfg.name);
    println!(
        "{:<10} {:<10} {:>10} {:>10}",
        "calib", "FT set", "wiki PPL", "web PPL"
    );
    println!("{}", "-".repeat(44));
    let mut records = Vec::new();
    for calib_name in ["wiki", "web"] {
        let calib = if calib_name == "wiki" { &wiki } else { &web };
        for ft_name in ["none", "wiki", "web"] {
            let ft = ft_name != "none";
            let mut o = pipeline_opts(ctx, Algo::WaterSic, rate, ft);
            if ft && ft_name != calib_name {
                // FT on a different corpus: re-run the FT stage manually
                o.finetune = None;
            }
            let mut run =
                run_config(ctx, &cfg, &teacher, calib, &wiki, &web, &o)?;
            if ft && ft_name != calib_name {
                let ft_corpus = if ft_name == "wiki" { &wiki } else { &web };
                ft_on_corpus(ctx, &cfg, &teacher, ft_corpus, &mut run)?;
            }
            println!(
                "{:<10} {:<10} {:>10.3} {:>10.3}",
                calib_name, ft_name, run.ppl_wiki, run.ppl_web
            );
            records.push(obj(vec![
                ("calib", Json::Str(calib_name.to_string())),
                ("ft", Json::Str(ft_name.to_string())),
                ("ppl_wiki", Json::Num(run.ppl_wiki)),
                ("ppl_web", Json::Num(run.ppl_web)),
            ]));
        }
    }
    println!("(each FT set should be best on its own evaluation distribution)");
    ctx.save_results("table15", Json::Arr(records));
    Ok(())
}

fn ft_on_corpus(
    ctx: &Ctx,
    cfg: &ModelConfig,
    teacher: &Weights,
    corpus: &Corpus,
    run: &mut RunOut,
) -> Result<()> {
    use crate::model::transformer::{forward, ForwardOpts};
    let windows = corpus.calib_windows(8, cfg.ctx, 771);
    let batches: Vec<Vec<i32>> = crate::calib::corpus::batch_windows(&windows, 4)
        .into_iter()
        .map(|(t, _)| t)
        .collect();
    let tlogits: Vec<crate::linalg::Mat> = batches
        .iter()
        .map(|t| forward(cfg, teacher, t, 4, cfg.ctx, &ForwardOpts::default()).logits)
        .collect();
    crate::ft::finetune_rescalers(
        cfg,
        &tlogits,
        &batches,
        4,
        &mut run.qm.student,
        &mut run.qm.quants,
        &FtOpts {
            steps: if ctx.fast { 10 } else { 24 },
            peak_lr: 5e-3,
            min_lr: 1e-4,
        },
    )?;
    // re-evaluate
    let n_eval = eval_count(ctx);
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    run.ppl_wiki = eval::perplexity_native(
        cfg,
        &run.qm.student,
        &wiki.eval_windows(n_eval, cfg.ctx, 1234),
    );
    run.ppl_web = eval::perplexity_native(
        cfg,
        &run.qm.student,
        &web.eval_windows(n_eval, cfg.ctx, 1234),
    );
    Ok(())
}

/// Fig. 12 analog: KL(BF16 ‖ quantized) vs rate.
pub fn fig12(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(ctx, &[1.5, 2.0, 2.5, 3.0, 4.0], &[2.0, 3.0]);
    let n_eval = if ctx.fast { 8 } else { 24 };
    let windows = wiki.eval_windows(n_eval, cfg.ctx, 555);
    println!("Fig. 12 analog — KL(P_BF16 ‖ P_quant), nats/token");
    println!(
        "{:>5} | {:>12} {:>12} {:>12}",
        "Rate", "HPTQ", "WaterSIC", "WaterSIC-FT"
    );
    println!("{}", "-".repeat(50));
    let mut records = Vec::new();
    for &rate in &rates {
        let mut row = Vec::new();
        for (algo, ft) in [
            (Algo::HuffGptq, false),
            (Algo::WaterSic, false),
            (Algo::WaterSic, true),
        ] {
            let o = pipeline_opts(ctx, algo, rate, ft);
            let r = run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?;
            row.push(eval::kl_to_teacher(&cfg, &teacher, &r.qm.student, &windows));
        }
        println!(
            "{:>5.2} | {:>12.4} {:>12.4} {:>12.4}",
            rate, row[0], row[1], row[2]
        );
        records.push(obj(vec![
            ("rate", Json::Num(rate)),
            ("kl_hptq", Json::Num(row[0])),
            ("kl_watersic", Json::Num(row[1])),
            ("kl_watersic_ft", Json::Num(row[2])),
        ]));
    }
    ctx.save_results("fig12", Json::Arr(records));
    Ok(())
}

/// Table 17 analog: probe-suite accuracy per method and rate.
pub fn tasks(ctx: &Ctx) -> Result<()> {
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    let rates = rate_grid(ctx, &[2.0, 3.0, 4.0], &[2.0, 3.0]);
    let n_eval = eval_count(ctx);
    let windows = wiki.eval_windows(n_eval, cfg.ctx, 808);
    println!("Table 17 analog — probe accuracies on wiki eval (higher better)");
    println!(
        "{:>5} {:<14} {:>8} {:>8} {:>9} {:>11}",
        "Rate", "Method", "top-1", "digits", "wordstart", "whitespace"
    );
    println!("{}", "-".repeat(60));
    let teach_probe = eval::probe_suite(&cfg, &teacher, &windows);
    println!(
        "{:>5} {:<14} {:>8.4} {:>8.4} {:>9.4} {:>11.4}",
        "BF16", "teacher", teach_probe.top1, teach_probe.digits,
        teach_probe.word_start, teach_probe.whitespace
    );
    let mut records = Vec::new();
    for &rate in &rates {
        for (label, algo, ft) in [
            ("Huffman-GPTQ", Algo::HuffGptq, false),
            ("WaterSIC", Algo::WaterSic, false),
            ("WaterSIC-FT", Algo::WaterSic, true),
        ] {
            let o = pipeline_opts(ctx, algo, rate, ft);
            let r = run_config(ctx, &cfg, &teacher, &wiki, &wiki, &web, &o)?;
            let p = eval::probe_suite(&cfg, &r.qm.student, &windows);
            println!(
                "{:>5.1} {:<14} {:>8.4} {:>8.4} {:>9.4} {:>11.4}",
                rate, label, p.top1, p.digits, p.word_start, p.whitespace
            );
            records.push(obj(vec![
                ("rate", Json::Num(rate)),
                ("method", Json::Str(label.to_string())),
                ("top1", Json::Num(p.top1)),
                ("digits", Json::Num(p.digits)),
                ("word_start", Json::Num(p.word_start)),
                ("whitespace", Json::Num(p.whitespace)),
            ]));
        }
        println!();
    }
    ctx.save_results("tasks", Json::Arr(records));
    Ok(())
}
