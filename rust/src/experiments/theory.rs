//! `repro theory` — the headline information-theoretic experiment
//! (Theorem 3.3): empirical rate gaps of entropy-coded GPTQ and
//! PlainWaterSIC to the reverse-waterfilling bound, over covariance
//! families of increasing conditioning.  Shape targets:
//!   * WaterSIC's gap → ½log₂(2πe/12) ≈ 0.255 bit, uniformly in Σ_X;
//!   * GPTQ's gap = 0.255 + AM/GM(ℓ_ii²) term, growing without bound.

use anyhow::Result;

use crate::linalg::chol::cholesky;
use crate::linalg::Mat;
use crate::quant::waterfilling::{
    amgm_gap_bits, ar1_sigma, gptq_gap_bits, r_wf, spectrum, spiked_sigma,
    SHAPING_GAP_BITS,
};
use crate::quant::zsic::{geomean_diag, gptq_alphas, watersic_alphas, zsic};
use crate::util::json::{arr_f64, obj, Json};
use crate::util::rng::Rng;

use super::Ctx;

struct GapPoint {
    rate: f64,
    gap_ws: f64,
    gap_gptq: f64,
}

/// Measure empirical (R, D) for both spacing rules at equal lattice
/// density and return gaps to R_WF.
fn measure(sigma: &Mat, a: usize, rate_grid: &[f64], seed: u64) -> Vec<GapPoint> {
    let n = sigma.rows;
    let mut rng = Rng::new(seed);
    let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
    let l = cholesky(sigma).expect("theory sigma must be PD");
    let y = crate::linalg::gemm::matmul(&w, &l);
    let lam = spectrum(sigma);
    let gm = geomean_diag(&l);

    rate_grid
        .iter()
        .map(|&target| {
            // same point density |A|^{1/n} = α for both algorithms
            let run = |watersic: bool, alpha: f64| -> (f64, f64) {
                let alphas = if watersic {
                    watersic_alphas(&l, alpha * gm)
                } else {
                    gptq_alphas(n, alpha)
                };
                let out = zsic(&y, &l, &alphas, false, None);
                let rate = crate::entropy::entropy_bits(&out.z);
                // D = ‖e_SIC‖²/(na) (resid is exactly the per-column error)
                let d = out.resid.data.iter().map(|x| x * x).sum::<f64>()
                    / (a * n) as f64;
                (rate, d)
            };
            // secant on α to hit the target entropy for each rule
            let solve = |watersic: bool| -> (f64, f64) {
                let rate_of = |alpha: f64| run(watersic, alpha).0;
                let a0 = (2.0 * std::f64::consts::PI * std::f64::consts::E)
                    .sqrt()
                    * 2f64.powf(-target);
                let alpha = crate::quant::rate_control::secant_scale(
                    rate_of, a0, target, 0.01, 8,
                );
                run(watersic, alpha)
            };
            let (r_ws, d_ws) = solve(true);
            let (r_gq, d_gq) = solve(false);
            GapPoint {
                rate: target,
                gap_ws: r_ws - r_wf(d_ws, &lam, 1.0),
                gap_gptq: r_gq - r_wf(d_gq, &lam, 1.0),
            }
        })
        .collect()
}

pub fn run(ctx: &Ctx) -> Result<()> {
    let (n, a) = if ctx.fast { (48, 384) } else { (96, 1024) };
    let rates: Vec<f64> = if ctx.fast {
        vec![3.0, 4.0]
    } else {
        vec![2.0, 3.0, 4.0, 5.0]
    };

    println!("Theorem 3.3 reproduction: rate gap to the waterfilling bound");
    println!("(n = {n}, {a} i.i.d. Gaussian rows; entropy-coded, no LMMSE)");
    println!();
    println!(
        "{:<22} {:>5} | {:>9} {:>9} | {:>9} {:>9}",
        "Σ_X family", "R", "WS gap", "theory", "GPTQ gap", "theory"
    );
    println!("{}", "-".repeat(74));

    let mut records = Vec::new();
    let families: Vec<(String, Mat)> = vec![
        ("white (I)".to_string(), Mat::eye(n)),
        ("AR(1) ρ=0.5".to_string(), ar1_sigma(n, 0.5)),
        ("AR(1) ρ=0.9".to_string(), ar1_sigma(n, 0.9)),
        ("AR(1) ρ=0.99".to_string(), ar1_sigma(n, 0.99)),
        ("spiked k=8 ×32".to_string(), spiked_sigma(n, 8, 32.0, 7)),
    ];

    for (name, sigma) in &families {
        let l = cholesky(sigma)?;
        let gptq_theory = gptq_gap_bits(&l.diag());
        let points = measure(sigma, a, &rates, 42);
        for p in &points {
            println!(
                "{:<22} {:>5.1} | {:>9.3} {:>9.3} | {:>9.3} {:>9.3}",
                name, p.rate, p.gap_ws, SHAPING_GAP_BITS, p.gap_gptq, gptq_theory
            );
            records.push(obj(vec![
                ("family", Json::Str(name.clone())),
                ("rate", Json::Num(p.rate)),
                ("gap_watersic", Json::Num(p.gap_ws)),
                ("gap_gptq", Json::Num(p.gap_gptq)),
                ("theory_watersic", Json::Num(SHAPING_GAP_BITS)),
                ("theory_gptq", Json::Num(gptq_theory)),
                ("amgm_term", Json::Num(amgm_gap_bits(&l.diag()))),
            ]));
        }
        // shape assertions printed as a verdict line
        let last = points.last().unwrap();
        let ws_ok = (last.gap_ws - SHAPING_GAP_BITS).abs() < 0.15;
        let gq_ok = last.gap_gptq >= last.gap_ws - 0.02;
        println!(
            "{:<22}       verdict: WaterSIC≈0.255 {}  GPTQ≥WaterSIC {}",
            "",
            if ws_ok { "✓" } else { "✗" },
            if gq_ok { "✓" } else { "✗" }
        );
    }
    println!();
    println!(
        "WaterSIC's gap is Σ-independent (rotation invariant); GPTQ's grows \
         with the AM/GM spread of the Cholesky diagonal — unboundedly as ρ→1."
    );
    ctx.save_results(
        "theory",
        obj(vec![
            ("n", Json::Num(n as f64)),
            ("a", Json::Num(a as f64)),
            ("rates", arr_f64(&rates)),
            ("records", Json::Arr(records)),
        ]),
    );
    Ok(())
}
