//! Experiment drivers: one function per paper table/figure (DESIGN.md §4).
//! Each prints the same rows/series the paper reports and dumps a JSON
//! record under `results/`.

pub mod diag;
pub mod llm;
pub mod theory;

use std::path::PathBuf;

use anyhow::{Context as _, Result};

use crate::calib::corpus::Corpus;
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::runtime::Engine;
use crate::util::json::Json;

/// Shared experiment context.
pub struct Ctx {
    pub artifacts: PathBuf,
    pub engine: Option<Engine>,
    /// reduced rate grids / calib sizes for quick smoke runs
    pub fast: bool,
    pub results_dir: PathBuf,
}

impl Ctx {
    pub fn new(fast: bool, use_engine: bool) -> Result<Ctx> {
        let artifacts = crate::artifacts_dir();
        let engine = if use_engine {
            match Engine::new(artifacts.clone()) {
                Ok(e) => {
                    eprintln!(
                        "[runtime] PJRT platform: {}; native kernels: {}",
                        e.platform(),
                        e.precision().name()
                    );
                    Some(e)
                }
                Err(e) => {
                    eprintln!("[runtime] PJRT unavailable ({e:#}); native fallback");
                    None
                }
            }
        } else {
            None
        };
        let results_dir = artifacts
            .parent()
            .map(|p| p.join("results"))
            .unwrap_or_else(|| "results".into());
        std::fs::create_dir_all(&results_dir).ok();
        Ok(Ctx {
            artifacts,
            engine,
            fast,
            results_dir,
        })
    }

    pub fn load_model(&self, name: &str) -> Result<(ModelConfig, Weights)> {
        let dir = self.artifacts.join("models").join(name);
        let cfg = ModelConfig::load(&dir.join("meta.json"))
            .with_context(|| format!("loading model {name} (run `make artifacts`)"))?;
        let w = Weights::load(&dir, &cfg)?;
        Ok((cfg, w))
    }

    pub fn load_corpus(&self, domain: &str) -> Result<Corpus> {
        Corpus::load(&self.artifacts, domain)
    }

    pub fn save_results(&self, id: &str, json: Json) {
        let path = self.results_dir.join(format!("{id}.json"));
        if let Err(e) = std::fs::write(&path, json.to_string_pretty()) {
            eprintln!("[results] failed to write {}: {e}", path.display());
        } else {
            eprintln!("[results] wrote {}", path.display());
        }
    }
}

/// Deterministic synthetic tiny-model setup (config, teacher weights,
/// calibration corpus): the zero-artifact path shared by the serving
/// CLI (`--model tiny`), the serve bench, the serve parity tests, and
/// CI's end-to-end determinism gate.  Every seed is pinned, so two
/// quantization runs of this setup must produce byte-identical `.wsic`
/// containers (across thread counts too — the kernel layer is
/// bit-deterministic).
pub fn synthetic_tiny_setup() -> (ModelConfig, Weights, Corpus) {
    let cfg = ModelConfig::tiny_test();
    let teacher = Weights::random(&cfg, 21);
    let text: String = (0..400)
        .map(|i| format!("alpha beta {} gamma. ", i % 37))
        .collect();
    let corpus = Corpus::from_bytes("synthetic", text.into_bytes());
    (cfg, teacher, corpus)
}

/// The matching cheap pipeline options (small calibration, no engine —
/// nothing artifact-dependent).
pub fn synthetic_tiny_opts(rate: f64) -> crate::coordinator::PipelineOpts {
    let mut opts = crate::coordinator::PipelineOpts::watersic(rate);
    opts.calib_windows = 4;
    opts.calib_batch = 2;
    opts.subsample_rows = 16;
    opts.use_engine = false;
    opts
}

/// Dispatch by experiment id (the `repro <id>` CLI).
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "theory" => theory::run(ctx),
        "table1" | "fig2" => llm::table1(ctx),
        "table2" | "fig3" => llm::table2(ctx),
        "fig1" => llm::fig1(ctx),
        "table7" => llm::table7(ctx),
        "table15" => llm::table15(ctx),
        "fig12" => llm::fig12(ctx),
        "tasks" | "table17" => llm::tasks(ctx),
        "fig4" => diag::fig4(ctx),
        "fig5" => diag::fig5(ctx),
        "table6" => diag::table6(ctx),
        "fig11" => diag::fig11(ctx),
        "ablate" | "fig6" | "fig7" | "fig8" | "fig10" => diag::ablate(ctx),
        "mixing" | "table3" | "table4" => diag::mixing(ctx),
        "all" => {
            for id in [
                "theory", "fig11", "fig5", "table6", "fig4", "ablate", "mixing",
                "table1", "table2", "fig1", "fig12", "table7", "table15", "tasks",
            ] {
                println!("\n================ repro {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; see DESIGN.md §4 for the index"
        ),
    }
}
