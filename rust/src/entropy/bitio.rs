//! Bit-level IO for the Huffman coder: MSB-first writer/reader with
//! u32 varint helpers for headers.

#[derive(Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.bytes.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the low `len` bits of `code`, MSB first.
    #[inline]
    pub fn put_bits(&mut self, code: u32, len: u8) {
        for k in (0..len).rev() {
            self.put_bit((code >> k) & 1 == 1);
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.bytes.push(self.cur);
        }
        self.bytes
    }

    pub fn bit_len(&self) -> usize {
        self.bytes.len() * 8 + self.nbits as usize
    }
}

pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    #[inline]
    pub fn get_bit(&mut self) -> anyhow::Result<bool> {
        let byte = self.pos / 8;
        if byte >= self.bytes.len() {
            anyhow::bail!("bitstream exhausted");
        }
        let bit = 7 - (self.pos % 8) as u32;
        self.pos += 1;
        Ok((self.bytes[byte] >> bit) & 1 == 1)
    }

    pub fn get_bits(&mut self, len: u8) -> anyhow::Result<u32> {
        let mut v = 0u32;
        for _ in 0..len {
            v = (v << 1) | self.get_bit()? as u32;
        }
        Ok(v)
    }
}

/// LEB128-style varint for unsigned headers.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

pub fn get_varint(bytes: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0;
    loop {
        let b = *bytes
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("varint truncated"))?;
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            anyhow::bail!("varint too long");
        }
    }
}

/// ZigZag map i32 ↔ u32 (small magnitudes → small codes).
#[inline]
pub fn zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

#[inline]
pub fn unzigzag(v: u32) -> i32 {
    ((v >> 1) as i32) ^ -((v & 1) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0b1, 1);
        w.put_bits(0x3ff, 10);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4).unwrap(), 0b1011);
        assert_eq!(r.get_bits(1).unwrap(), 1);
        assert_eq!(r.get_bits(10).unwrap(), 0x3ff);
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        for &v in &vals {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-1000, -1, 0, 1, 5, i32::MAX / 2, i32::MIN / 2] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn reader_detects_exhaustion() {
        let mut r = BitReader::new(&[0xff]);
        assert!(r.get_bits(8).is_ok());
        assert!(r.get_bit().is_err());
    }
}
