//! Canonical Huffman coder over i32 symbols — the "Huffman-GPTQ /
//! Huffman-RTN" coder of the paper.  Handles arbitrary alphabets via a
//! (symbol table + canonical code length) header; decode is table-free
//! canonical (sorted first-code method).

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::bitio::{get_varint, put_varint, unzigzag, zigzag, BitReader, BitWriter};
use super::Codec;

const MAX_CODE_LEN: u8 = 32;

pub struct Huffman;

/// Build canonical code lengths for the given counts using the standard
/// two-queue Huffman construction, then canonicalize.
fn code_lengths(counts: &[(u32, u64)]) -> Vec<(u32, u8)> {
    let n = counts.len();
    if n == 1 {
        return vec![(counts[0].0, 1)];
    }
    // heap of (weight, node). leaves 0..n, internal nodes n..
    #[derive(PartialEq, Eq)]
    struct Item(u64, usize);
    impl Ord for Item {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            o.0.cmp(&self.0).then(o.1.cmp(&self.1)) // min-heap
        }
    }
    impl PartialOrd for Item {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    let mut heap: std::collections::BinaryHeap<Item> = counts
        .iter()
        .enumerate()
        .map(|(i, &(_, c))| Item(c.max(1), i))
        .collect();
    let mut parent = vec![usize::MAX; 2 * n - 1];
    let mut next = n;
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        parent[a.1] = next;
        parent[b.1] = next;
        heap.push(Item(a.0 + b.0, next));
        next += 1;
    }
    let mut lens: Vec<(u32, u8)> = Vec::with_capacity(n);
    for (i, &(sym, _)) in counts.iter().enumerate() {
        let mut d = 0u8;
        let mut node = i;
        while parent[node] != usize::MAX {
            node = parent[node];
            d += 1;
        }
        lens.push((sym, d.min(MAX_CODE_LEN)));
    }
    lens
}

/// Assign canonical codes given (symbol, len) sorted by (len, symbol).
fn canonical_codes(lens: &mut Vec<(u32, u8)>) -> HashMap<u32, (u32, u8)> {
    lens.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
    let mut codes = HashMap::new();
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &(sym, len) in lens.iter() {
        code <<= len - prev_len;
        codes.insert(sym, (code, len));
        code += 1;
        prev_len = len;
    }
    codes
}

impl Codec for Huffman {
    fn name(&self) -> &'static str {
        "huffman"
    }

    fn encode(&self, symbols: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        put_varint(&mut out, symbols.len() as u64);
        if symbols.is_empty() {
            return out;
        }
        let hist = super::histogram(symbols);
        let mut counts: Vec<(u32, u64)> =
            hist.iter().map(|(&s, &c)| (zigzag(s), c)).collect();
        counts.sort_unstable();
        let mut lens = code_lengths(&counts);
        let codes = canonical_codes(&mut lens);
        // header: alphabet size, then (zigzag sym varint, len byte) in
        // canonical order
        put_varint(&mut out, lens.len() as u64);
        for &(sym, len) in &lens {
            put_varint(&mut out, sym as u64);
            out.push(len);
        }
        let mut bw = BitWriter::new();
        for &s in symbols {
            let (code, len) = codes[&zigzag(s)];
            bw.put_bits(code, len);
        }
        let payload = bw.finish();
        put_varint(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
        out
    }

    fn decode(&self, bytes: &[u8], n_expected: usize) -> Result<Vec<i32>> {
        let mut pos = 0;
        let n = get_varint(bytes, &mut pos)? as usize;
        if n != n_expected {
            bail!("length mismatch: header {n}, expected {n_expected}");
        }
        if n == 0 {
            return Ok(Vec::new());
        }
        let k = get_varint(bytes, &mut pos)? as usize;
        let mut lens: Vec<(u32, u8)> = Vec::with_capacity(k);
        for _ in 0..k {
            let sym = get_varint(bytes, &mut pos)? as u32;
            let len = *bytes
                .get(pos)
                .ok_or_else(|| anyhow::anyhow!("truncated header"))?;
            pos += 1;
            lens.push((sym, len));
        }
        let payload_len = get_varint(bytes, &mut pos)? as usize;
        let payload = bytes
            .get(pos..pos + payload_len)
            .ok_or_else(|| anyhow::anyhow!("truncated payload"))?;

        // canonical decode tables: first_code/first_index per length
        let max_len = lens.iter().map(|l| l.1).max().unwrap_or(1) as usize;
        let mut count_by_len = vec![0u32; max_len + 1];
        for &(_, len) in &lens {
            count_by_len[len as usize] += 1;
        }
        let mut first_code = vec![0u32; max_len + 2];
        let mut first_idx = vec![0u32; max_len + 2];
        let mut code = 0u32;
        let mut idx = 0u32;
        for l in 1..=max_len {
            first_code[l] = code;
            first_idx[l] = idx;
            code = (code + count_by_len[l]) << 1;
            idx += count_by_len[l];
        }
        // symbols in canonical order (lens is already canonical-sorted
        // from the encoder; enforce)
        let mut lens_sorted = lens.clone();
        lens_sorted.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
        let syms: Vec<u32> = lens_sorted.iter().map(|l| l.0).collect();

        let mut br = BitReader::new(payload);
        let mut out = Vec::with_capacity(n);
        if k == 1 {
            // degenerate single-symbol alphabet: 1-bit codes
            for _ in 0..n {
                br.get_bit()?;
                out.push(unzigzag(syms[0]));
            }
            return Ok(out);
        }
        for _ in 0..n {
            let mut code = 0u32;
            let mut len = 0usize;
            loop {
                code = (code << 1) | br.get_bit()? as u32;
                len += 1;
                if len > max_len {
                    bail!("invalid code");
                }
                let nl = count_by_len[len];
                if nl > 0 && code >= first_code[len] && code < first_code[len] + nl
                {
                    let sym_idx = first_idx[len] + (code - first_code[len]);
                    out.push(unzigzag(syms[sym_idx as usize]));
                    break;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(symbols: &[i32]) {
        let h = Huffman;
        let enc = h.encode(symbols);
        let dec = h.decode(&enc, symbols.len()).unwrap();
        assert_eq!(dec, symbols);
    }

    #[test]
    fn roundtrips() {
        roundtrip(&[]);
        roundtrip(&[0]);
        roundtrip(&[5; 100]);
        roundtrip(&[-1, 0, 1, 2, -2, 0, 0, 0, 1]);
        roundtrip(&(0..1000).map(|i| (i * i) % 17 - 8).collect::<Vec<_>>());
    }

    #[test]
    fn near_entropy_on_gaussian_codes() {
        let mut rng = Rng::new(5);
        let z: Vec<i32> = (0..50_000)
            .map(|_| (rng.gaussian() * 3.0).round() as i32)
            .collect();
        let h = Huffman;
        let rate = h.rate(&z);
        let ent = super::super::entropy_bits(&z);
        // Huffman within 0.1 bit + header overhead of entropy here
        assert!(rate < ent + 0.15, "rate {rate} vs entropy {ent}");
        assert!(rate >= ent - 1e-9);
        roundtrip(&z);
    }

    #[test]
    fn handles_outliers() {
        // entropy coding absorbs rare huge integers (paper §1)
        let mut z = vec![0i32; 10_000];
        z[17] = 1 << 20;
        z[400] = -(1 << 19);
        roundtrip(&z);
        let rate = Huffman.rate(&z);
        // Huffman's floor is 1 bit/symbol; the point is that the two huge
        // integers cost a few dozen bits total, not 20+ bits/symbol.
        assert!(rate < 1.1, "outliers must not blow up the rate: {rate}");
    }

    #[test]
    fn decode_rejects_wrong_length() {
        let enc = Huffman.encode(&[1, 2, 3]);
        assert!(Huffman.decode(&enc, 4).is_err());
    }
}
