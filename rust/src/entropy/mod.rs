//! Entropy-coding substrate: empirical entropy estimation, canonical
//! Huffman, rANS, and wrappers over real zstd / DEFLATE for the Table 6
//! comparison.  All coders operate on i32 symbol streams (the ZSIC
//! integer codes) and round-trip bit-exactly.

pub mod bitio;
pub mod external;
pub mod huffman;
pub mod rans;

use std::collections::HashMap;

/// Histogram of an i32 symbol stream.
pub fn histogram(symbols: &[i32]) -> HashMap<i32, u64> {
    let mut h = HashMap::new();
    for &s in symbols {
        *h.entry(s).or_insert(0u64) += 1;
    }
    h
}

/// Empirical Shannon entropy in bits/symbol.
pub fn entropy_bits(symbols: &[i32]) -> f64 {
    if symbols.is_empty() {
        return 0.0;
    }
    let h = histogram(symbols);
    let n = symbols.len() as f64;
    let mut e = 0.0;
    for &c in h.values() {
        let p = c as f64 / n;
        e -= p * p.log2();
    }
    e
}

/// Entropy of each column of an (a × n) row-major code matrix —
/// the per-in-channel rates of Fig. 5.
pub fn column_entropies(z: &[i32], a: usize, n: usize) -> Vec<f64> {
    assert_eq!(z.len(), a * n);
    (0..n)
        .map(|j| {
            let col: Vec<i32> = (0..a).map(|i| z[i * n + j]).collect();
            entropy_bits(&col)
        })
        .collect()
}

/// Mean of per-column entropies — the theoretical per-column coded rate
/// (eq. 8–10 context); joint entropy over the whole matrix is what the
/// practical WaterSIC reports.
pub fn mean_column_entropy(z: &[i32], a: usize, n: usize) -> f64 {
    let cols = column_entropies(z, a, n);
    cols.iter().sum::<f64>() / cols.len().max(1) as f64
}

/// Coded rate in bits/entry under *per-column* entropy coding — the
/// measure of Algorithm 2 (each column gets its own code).  Uses the
/// Miller–Madow bias correction H += (k−1)/(2N ln 2), without which the
/// plug-in estimate is badly optimistic for short columns (small a).
/// At LLM scale (a ≥ 2048) this agrees with the joint entropy to ~0.01
/// bits (paper §4 "Entropy coding"); at picollama scale they differ, and
/// this is the faithful quantity.
pub fn column_coded_rate(z: &[i32], a: usize, n: usize) -> f64 {
    assert_eq!(z.len(), a * n);
    let ln2 = std::f64::consts::LN_2;
    let mut total = 0.0;
    for j in 0..n {
        let col: Vec<i32> = (0..a).map(|i| z[i * n + j]).collect();
        let h = histogram(&col);
        let mut e = 0.0;
        for &c in h.values() {
            let p = c as f64 / a as f64;
            e -= p * p.log2();
        }
        let k = h.len() as f64;
        total += e + (k - 1.0) / (2.0 * a as f64 * ln2);
    }
    total / n as f64
}

/// A lossless i32 codec.
pub trait Codec {
    fn name(&self) -> &'static str;
    fn encode(&self, symbols: &[i32]) -> Vec<u8>;
    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<i32>>;

    /// Achieved rate in bits/symbol.
    fn rate(&self, symbols: &[i32]) -> f64 {
        if symbols.is_empty() {
            return 0.0;
        }
        8.0 * self.encode(symbols).len() as f64 / symbols.len() as f64
    }
}

/// Pack i32 codes into the smallest sufficient little-endian integer
/// type (i8 or i16 or i32), column-major as in the paper's Table 6 setup
/// ("entries sharing the same input feature are contiguous").
pub fn pack_column_major(z: &[i32], a: usize, n: usize) -> Vec<u8> {
    assert_eq!(z.len(), a * n);
    let (lo, hi) = z
        .iter()
        .fold((i32::MAX, i32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    let mut out = Vec::new();
    if lo >= i8::MIN as i32 && hi <= i8::MAX as i32 {
        for j in 0..n {
            for i in 0..a {
                out.push(z[i * n + j] as i8 as u8);
            }
        }
    } else if lo >= i16::MIN as i32 && hi <= i16::MAX as i32 {
        for j in 0..n {
            for i in 0..a {
                out.extend_from_slice(&(z[i * n + j] as i16).to_le_bytes());
            }
        }
    } else {
        for j in 0..n {
            for i in 0..a {
                out.extend_from_slice(&z[i * n + j].to_le_bytes());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform_and_constant() {
        let z: Vec<i32> = (0..1024).map(|i| i % 8).collect();
        assert!((entropy_bits(&z) - 3.0).abs() < 1e-9);
        assert_eq!(entropy_bits(&vec![5; 100]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
    }

    #[test]
    fn column_entropies_distinguish() {
        // col 0 constant, col 1 binary
        let z = vec![0, 0, 0, 1, 0, 0, 0, 1]; // 4x2
        let ce = column_entropies(&z, 4, 2);
        assert_eq!(ce[0], 0.0);
        assert!((ce[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pack_picks_smallest_width() {
        let z = vec![-1, 0, 1, 2];
        assert_eq!(pack_column_major(&z, 2, 2).len(), 4); // i8
        let z16 = vec![300, 0, -300, 5];
        assert_eq!(pack_column_major(&z16, 2, 2).len(), 8); // i16
        let z32 = vec![70000, 0, 1, 2];
        assert_eq!(pack_column_major(&z32, 2, 2).len(), 16); // i32
    }

    #[test]
    fn pack_is_column_major() {
        let z = vec![1, 2, 3, 4]; // [[1,2],[3,4]]
        let p = pack_column_major(&z, 2, 2);
        assert_eq!(p, vec![1, 3, 2, 4]);
    }
}
