//! rANS (range asymmetric numeral system) coder, 32-bit state with
//! 16-bit renormalization and 12-bit quantized frequencies.  This is the
//! production coder used by the compressed-model container: ~entropy-
//! optimal like arithmetic coding but decode is a table lookup + two
//! multiplies per symbol.

use anyhow::{bail, Result};

use super::bitio::{get_varint, put_varint, unzigzag, zigzag};
use super::Codec;

const PROB_BITS: u32 = 12;
const PROB_SCALE: u32 = 1 << PROB_BITS;
const RANS_L: u32 = 1 << 16; // lower bound of the normalization interval

pub struct Rans;

struct SymStats {
    /// quantized frequency per symbol (sums to PROB_SCALE)
    freq: Vec<u32>,
    /// cumulative frequency
    cum: Vec<u32>,
    /// symbol values (zigzagged), canonical order
    syms: Vec<u32>,
}

/// Index of the largest quantized frequency.  Total on any input:
/// every live entry is ≥ 1 (see the `.max(1)` below), so the first
/// element always beats the starting best of 0; an empty table —
/// unreachable from the codec, which rejects empty payloads — yields 0
/// rather than panicking.
fn argmax_freq(freq: &[u32]) -> usize {
    let mut best = 0usize;
    let mut best_f = 0u32;
    for (i, &f) in freq.iter().enumerate() {
        if f > best_f {
            best = i;
            best_f = f;
        }
    }
    best
}

/// Quantize empirical counts to 12-bit frequencies that sum exactly to
/// PROB_SCALE, every present symbol getting freq ≥ 1.
fn quantize_freqs(counts: &[(u32, u64)]) -> SymStats {
    let total: u64 = counts.iter().map(|c| c.1).sum();
    let k = counts.len();
    assert!(k as u32 <= PROB_SCALE, "alphabet too large for 12-bit rANS");
    let mut freq: Vec<u32> = counts
        .iter()
        .map(|&(_, c)| {
            (((c as u128 * PROB_SCALE as u128) / total as u128) as u32).max(1)
        })
        .collect();
    // fix the sum to exactly PROB_SCALE by adjusting the largest entries
    let mut sum: i64 = freq.iter().map(|&f| f as i64).sum();
    while sum != PROB_SCALE as i64 {
        if sum > PROB_SCALE as i64 {
            // shrink the largest freq > 1
            let i = argmax_freq(&freq);
            if freq[i] <= 1 {
                break;
            }
            let d = ((sum - PROB_SCALE as i64) as u32).min(freq[i] - 1);
            freq[i] -= d;
            sum -= d as i64;
        } else {
            let i = argmax_freq(&freq);
            let d = (PROB_SCALE as i64 - sum) as u32;
            freq[i] += d;
            sum += d as i64;
        }
    }
    let mut cum = vec![0u32; k + 1];
    for i in 0..k {
        cum[i + 1] = cum[i] + freq[i];
    }
    SymStats {
        freq,
        cum,
        syms: counts.iter().map(|c| c.0).collect(),
    }
}

impl Codec for Rans {
    fn name(&self) -> &'static str {
        "rans"
    }

    fn encode(&self, symbols: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        put_varint(&mut out, symbols.len() as u64);
        if symbols.is_empty() {
            return out;
        }
        let hist = super::histogram(symbols);
        let mut counts: Vec<(u32, u64)> =
            hist.iter().map(|(&s, &c)| (zigzag(s), c)).collect();
        counts.sort_unstable();
        let st = quantize_freqs(&counts);

        // header: alphabet + frequencies
        put_varint(&mut out, counts.len() as u64);
        for i in 0..counts.len() {
            put_varint(&mut out, st.syms[i] as u64);
            put_varint(&mut out, st.freq[i] as u64);
        }

        // symbol → index map
        let idx: std::collections::HashMap<u32, usize> = st
            .syms
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, i))
            .collect();

        // rANS encodes in reverse so the decoder reads forward
        let mut state: u32 = RANS_L;
        let mut stream: Vec<u16> = Vec::new();
        for &s in symbols.iter().rev() {
            let i = idx[&zigzag(s)];
            let f = st.freq[i];
            let c = st.cum[i];
            // renormalize: keep state < (RANS_L >> PROB_BITS) << 16) * f
            let x_max = ((RANS_L as u64 >> PROB_BITS) << 16) * f as u64;
            while state as u64 >= x_max {
                stream.push((state & 0xffff) as u16);
                state >>= 16;
            }
            state = (state / f) * PROB_SCALE + (state % f) + c;
        }
        put_varint(&mut out, state as u64);
        put_varint(&mut out, stream.len() as u64);
        // stream was pushed encoder-order; decoder pops from the end
        for w in &stream {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], n_expected: usize) -> Result<Vec<i32>> {
        let mut pos = 0;
        let n = get_varint(bytes, &mut pos)? as usize;
        if n != n_expected {
            bail!("length mismatch: header {n}, expected {n_expected}");
        }
        if n == 0 {
            return Ok(Vec::new());
        }
        let k = get_varint(bytes, &mut pos)? as usize;
        // the encoder asserts alphabet ≤ PROB_SCALE; a bigger k in the
        // header is corruption and must not drive a giant reservation
        if k == 0 || k > PROB_SCALE as usize {
            bail!("corrupt rANS header: alphabet size {k}");
        }
        let mut syms = Vec::with_capacity(k);
        let mut freq = Vec::with_capacity(k);
        for _ in 0..k {
            syms.push(get_varint(bytes, &mut pos)? as u32);
            let f = get_varint(bytes, &mut pos)?;
            if f > PROB_SCALE as u64 {
                bail!("corrupt rANS frequency {f}");
            }
            freq.push(f as u32);
        }
        let mut cum = vec![0u32; k + 1];
        for i in 0..k {
            // freqs are individually ≤ PROB_SCALE and k ≤ PROB_SCALE,
            // so the u64 sum cannot overflow; bail as soon as the
            // running total leaves the legal range
            let c = cum[i] as u64 + freq[i] as u64;
            if c > PROB_SCALE as u64 {
                bail!("corrupt rANS frequency table");
            }
            cum[i + 1] = c as u32;
        }
        if cum[k] != PROB_SCALE {
            bail!("corrupt rANS frequency table");
        }
        // slot → symbol index lookup
        let mut slot2sym = vec![0u16; PROB_SCALE as usize];
        for i in 0..k {
            for s in cum[i]..cum[i + 1] {
                slot2sym[s as usize] = i as u16;
            }
        }
        let mut state = get_varint(bytes, &mut pos)? as u32;
        let nwords = get_varint(bytes, &mut pos)? as usize;
        let words_start = pos;
        let words_end = nwords
            .checked_mul(2)
            .and_then(|b| words_start.checked_add(b));
        match words_end {
            Some(end) if end <= bytes.len() => {}
            _ => bail!("truncated rANS stream"),
        }
        let mut widx = nwords; // pop from the end

        // capacity is a hint: cap the up-front reservation so a huge
        // (but header-consistent) n cannot reserve memory the stream
        // never backs
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let slot = state & (PROB_SCALE - 1);
            let i = slot2sym[slot as usize] as usize;
            out.push(unzigzag(syms[i]));
            state = freq[i] * (state >> PROB_BITS) + slot - cum[i];
            while state < RANS_L {
                if widx == 0 {
                    bail!("rANS stream underflow");
                }
                widx -= 1;
                let off = words_start + 2 * widx;
                let w = u16::from_le_bytes([bytes[off], bytes[off + 1]]);
                state = (state << 16) | w as u32;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(symbols: &[i32]) {
        let enc = Rans.encode(symbols);
        let dec = Rans.decode(&enc, symbols.len()).unwrap();
        assert_eq!(dec, symbols);
    }

    #[test]
    fn roundtrips() {
        roundtrip(&[]);
        roundtrip(&[42]);
        roundtrip(&[7; 5000]);
        roundtrip(&[-3, -2, -1, 0, 1, 2, 3, 0, 0, 0, -1, 1]);
        let mut rng = Rng::new(9);
        let z: Vec<i32> = (0..30_000)
            .map(|_| (rng.gaussian() * 2.0).round_ties_even() as i32)
            .collect();
        roundtrip(&z);
    }

    #[test]
    fn corrupt_headers_error_not_panic() {
        // a crafted header with a giant alphabet size (or frequency)
        // must error instead of reserving giant Vecs / overflowing
        // alphabet size u64::MAX
        let mut b = Vec::new();
        put_varint(&mut b, 4); // n
        put_varint(&mut b, u64::MAX); // k
        assert!(Rans.decode(&b, 4).is_err());
        // plausible k but overflowing frequencies
        let mut b = Vec::new();
        put_varint(&mut b, 4);
        put_varint(&mut b, 1); // one symbol
        put_varint(&mut b, 0); // sym
        put_varint(&mut b, u64::MAX); // freq
        assert!(Rans.decode(&b, 4).is_err());
        // giant word count on a short buffer
        let mut b = Vec::new();
        put_varint(&mut b, 4);
        put_varint(&mut b, 1);
        put_varint(&mut b, 0);
        put_varint(&mut b, 1 << PROB_BITS); // freq = full scale
        put_varint(&mut b, RANS_L as u64); // state
        put_varint(&mut b, u64::MAX); // nwords
        assert!(Rans.decode(&b, 4).is_err());
        // truncating a valid stream anywhere must error too
        let enc = Rans.encode(&[1, -2, 3, -4, 5, 5, 5]);
        for cut in 0..enc.len() {
            assert!(Rans.decode(&enc[..cut], 7).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn near_entropy() {
        let mut rng = Rng::new(10);
        let z: Vec<i32> = (0..100_000)
            .map(|_| (rng.gaussian() * 4.0).round() as i32)
            .collect();
        let rate = Rans.rate(&z);
        let ent = super::super::entropy_bits(&z);
        assert!(
            rate < ent + 0.06,
            "rANS should be near-optimal: {rate} vs {ent}"
        );
    }

    #[test]
    fn skewed_distribution() {
        // 99% zeros: rate must approach H ≈ 0.08 bits, not 1 bit
        let mut rng = Rng::new(11);
        let z: Vec<i32> = (0..200_000)
            .map(|_| if rng.uniform() < 0.99 { 0 } else { rng.below(7) as i32 - 3 })
            .collect();
        roundtrip(&z);
        let rate = Rans.rate(&z);
        let ent = super::super::entropy_bits(&z);
        assert!(rate < ent + 0.05, "{rate} vs {ent}");
    }

    #[test]
    fn freq_quantization_sums() {
        let counts = vec![(0u32, 1u64), (1, 1_000_000), (2, 3), (3, 17)];
        let st = quantize_freqs(&counts);
        assert_eq!(st.freq.iter().sum::<u32>(), PROB_SCALE);
        assert!(st.freq.iter().all(|&f| f >= 1));
    }

    #[test]
    fn decode_rejects_corruption() {
        let enc = Rans.encode(&[1, 2, 3, 4, 5]);
        assert!(Rans.decode(&enc, 6).is_err());
        let mut bad = enc.clone();
        let last = bad.len() - 1;
        bad.truncate(last.saturating_sub(1));
        // may error or mis-decode, but must not panic
        let _ = Rans.decode(&bad, 5);
    }
}
