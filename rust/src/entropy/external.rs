//! Wrappers over general-purpose codecs (zstd, DEFLATE) operating
//! on the paper's Table 6 byte layout: integer codes packed
//! column-major into the smallest sufficient integer type.
//!
//! **Offline-build caveat:** this workspace currently links the
//! vendored stand-ins in `rust/vendor/{zstd,flate2}`, which implement
//! the same API over an order-0 canonical-Huffman byte codec — real,
//! round-trip-exact compression, but NOT the zstd/DEFLATE formats and
//! with no LZ77 matching.  Numbers reported through these wrappers are
//! then an order-0 upper bound on what the real codecs would achieve;
//! repoint Cargo.toml at the crates.io releases to reproduce Table 6's
//! actual zstd/deflate measurements.

use anyhow::Result;

use super::{pack_column_major, Codec};

/// Bits/parameter achieved by the linked zstd implementation at max
/// level on the packed byte stream — Table 6's "zstd (bpp)" column
/// when the real `zstd` crate is linked (see module caveat).
pub fn zstd_bpp(z: &[i32], a: usize, n: usize) -> f64 {
    let packed = pack_column_major(z, a, n);
    let comp = zstd::bulk::compress(&packed, 22).expect("zstd compress");
    8.0 * comp.len() as f64 / (a * n) as f64
}

/// Bits/parameter for the linked DEFLATE implementation (flate2 best) —
/// stands in for the paper's LZMA column (see module caveat).
pub fn deflate_bpp(z: &[i32], a: usize, n: usize) -> f64 {
    use flate2::write::ZlibEncoder;
    use flate2::Compression;
    use std::io::Write;
    let packed = pack_column_major(z, a, n);
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::best());
    enc.write_all(&packed).expect("deflate write");
    let comp = enc.finish().expect("deflate finish");
    8.0 * comp.len() as f64 / (a * n) as f64
}

/// zstd round-trip as an i32 `Codec` (container-format alternative to
/// rANS; kept for ablation benches).
pub struct ZstdCodec;

impl Codec for ZstdCodec {
    fn name(&self) -> &'static str {
        "zstd"
    }

    fn encode(&self, symbols: &[i32]) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(4 * symbols.len());
        for &s in symbols {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        zstd::bulk::compress(&bytes, 19).expect("zstd compress")
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<i32>> {
        let raw = zstd::bulk::decompress(bytes, 4 * n)?;
        if raw.len() != 4 * n {
            anyhow::bail!("zstd payload length mismatch");
        }
        Ok((0..n)
            .map(|i| i32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::entropy_bits;
    use crate::util::rng::Rng;

    fn gaussian_codes(n: usize, sigma: f64, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.gaussian() * sigma).round() as i32).collect()
    }

    #[test]
    fn zstd_roundtrip() {
        let z = gaussian_codes(10_000, 2.0, 1);
        let c = ZstdCodec;
        let enc = c.encode(&z);
        assert_eq!(c.decode(&enc, z.len()).unwrap(), z);
    }

    #[test]
    fn external_codecs_near_entropy() {
        // the Table 6 claim: general-purpose codecs land within a few
        // tenths of a bit of the empirical entropy on iid codes
        let a = 256;
        let n = 128;
        let z = gaussian_codes(a * n, 1.5, 2);
        let ent = entropy_bits(&z);
        let zr = zstd_bpp(&z, a, n);
        let dr = deflate_bpp(&z, a, n);
        assert!(zr > ent - 0.02, "cannot beat entropy: {zr} vs {ent}");
        assert!(zr < ent + 0.6, "zstd too far above entropy: {zr} vs {ent}");
        assert!(dr < ent + 1.0, "deflate too far above entropy: {dr} vs {ent}");
    }

    #[test]
    fn packing_width_affects_rate_not_correctness() {
        let z: Vec<i32> = (0..1024).map(|i| (i % 3) - 1).collect();
        let bpp8 = zstd_bpp(&z, 32, 32);
        assert!(bpp8 < 8.0); // int8 packing upper bound
    }
}
