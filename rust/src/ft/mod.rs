//! WaterSIC-FT (§4 "Post-quantization finetuning"): Adam on the
//! continuous rescalers (t, γ) of every quantized matrix under the
//! end-to-end distillation loss KL(P_teacher ‖ P_student), with the
//! integer codes Z frozen.  Gradients flow through Ŵ = T·(Z∘α)·Γ via the
//! native reverse pass (`model::autograd`) — no straight-through
//! estimator is needed because (t, γ) enter Ŵ smoothly.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::linalg::Mat;
use crate::model::autograd::{backward, kl_grad};
use crate::model::transformer::{forward, kl_divergence, ForwardOpts};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::quant::LayerQuant;

#[derive(Clone, Debug)]
pub struct FtOpts {
    pub steps: usize,
    pub peak_lr: f64,
    pub min_lr: f64,
}

impl Default for FtOpts {
    fn default() -> Self {
        FtOpts {
            steps: 24,
            peak_lr: 5e-4,
            min_lr: 5e-6,
        }
    }
}

struct AdamState {
    m: Vec<f64>,
    v: Vec<f64>,
}

impl AdamState {
    fn new(n: usize) -> Self {
        AdamState {
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    fn update(&mut self, params: &mut [f64], grads: &[f64], lr: f64, t: f64) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            let mh = self.m[i] / (1.0 - B1.powf(t));
            let vh = self.v[i] / (1.0 - B2.powf(t));
            params[i] -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

/// Rebuild the student weight matrix of `name` from its quant state.
fn rebuild(student: &mut Weights, name: &str, q: &LayerQuant) {
    student.set(name, q.dequant());
}

/// Finetune (t, γ) of all quantized matrices; mutates `quants` and the
/// corresponding student weights in place.  Returns the loss trace.
pub fn finetune_rescalers(
    cfg: &ModelConfig,
    teacher_logits: &[Mat],
    batches: &[Vec<i32>],
    b: usize,
    student: &mut Weights,
    quants: &mut BTreeMap<String, LayerQuant>,
    opts: &FtOpts,
) -> Result<Vec<f64>> {
    assert_eq!(teacher_logits.len(), batches.len());
    let names: Vec<String> = quants.keys().cloned().collect();
    let mut adam_t: BTreeMap<String, AdamState> = names
        .iter()
        .map(|n| (n.clone(), AdamState::new(quants[n].a)))
        .collect();
    let mut adam_g: BTreeMap<String, AdamState> = names
        .iter()
        .map(|n| (n.clone(), AdamState::new(quants[n].n)))
        .collect();
    let mut trace = Vec::with_capacity(opts.steps);

    for step in 0..opts.steps {
        let bi = step % batches.len();
        let toks = &batches[bi];
        // cosine LR schedule
        let lr = opts.min_lr
            + 0.5
                * (opts.peak_lr - opts.min_lr)
                * (1.0 + (std::f64::consts::PI * step as f64 / opts.steps as f64).cos());

        let out = forward(
            cfg,
            student,
            toks,
            b,
            cfg.ctx,
            &ForwardOpts {
                capture: false,
                tape: true,
                ..ForwardOpts::default()
            },
        );
        let loss = kl_divergence(&teacher_logits[bi], &out.logits);
        trace.push(loss);
        let dlogits = kl_grad(&teacher_logits[bi], &out.logits);
        let grads = backward(cfg, student, out.tape.as_ref().unwrap(), &dlogits);

        for name in &names {
            let q = quants.get_mut(name).unwrap();
            let g = &grads[name];
            // chain rule through Ŵ_ij = t_i · z_ij α_j γ_j
            let mut dt = vec![0.0; q.a];
            let mut dg = vec![0.0; q.n];
            for i in 0..q.a {
                let grow = g.row(i);
                let mut acc_t = 0.0;
                for j in 0..q.n {
                    let base = q.z[i * q.n + j] as f64 * q.alphas[j];
                    acc_t += grow[j] * base * q.gammas[j];
                    dg[j] += grow[j] * q.t[i] * base;
                }
                dt[i] = acc_t;
            }
            adam_t
                .get_mut(name)
                .unwrap()
                .update(&mut q.t, &dt, lr, (step + 1) as f64);
            adam_g
                .get_mut(name)
                .unwrap()
                .update(&mut q.gammas, &dg, lr, (step + 1) as f64);
            rebuild(student, name, q);
        }
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::watersic::watersic_at_rate;
    use crate::quant::{LayerStats, QuantOpts};
    use crate::util::rng::Rng;

    #[test]
    fn ft_reduces_distillation_loss() {
        let mut cfg = ModelConfig::tiny_test();
        cfg.ctx = 10;
        let teacher = Weights::random(&cfg, 7);
        let mut rng = Rng::new(3);
        let b = 2;
        let batches: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                (0..b * cfg.ctx)
                    .map(|_| rng.below(cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let tlogits: Vec<Mat> = batches
            .iter()
            .map(|t| {
                forward(&cfg, &teacher, t, b, cfg.ctx, &ForwardOpts::default()).logits
            })
            .collect();

        // quantize all 7 matrices coarsely but above the side-info
        // overhead floor (tiny shapes pay 16/a+16/n ≈ 1.5–2 bits)
        let mut student = teacher.clone();
        let mut quants = BTreeMap::new();
        for name in cfg.quantizable.clone() {
            let w = teacher.get(&name).clone();
            // crude white-ish stats suffice for this unit test
            let mut sigma = crate::linalg::Mat::eye(w.cols);
            sigma.add_diag(0.01);
            let stats = LayerStats::from_sigma(sigma);
            let q = watersic_at_rate(
                &w,
                &stats,
                3.5,
                &QuantOpts {
                    rescalers: false,
                    ..QuantOpts::default()
                },
                None,
                64,
                0,
            )
            .unwrap();
            student.set(&name, q.dequant());
            quants.insert(name, q);
        }
        let loss0 = {
            let out = forward(&cfg, &student, &batches[0], b, cfg.ctx,
                              &ForwardOpts::default());
            kl_divergence(&tlogits[0], &out.logits)
        };
        let trace = finetune_rescalers(
            &cfg,
            &tlogits,
            &batches,
            b,
            &mut student,
            &mut quants,
            &FtOpts {
                steps: 30,
                peak_lr: 2e-2,
                min_lr: 1e-4,
            },
        )
        .unwrap();
        let loss1 = {
            let out = forward(&cfg, &student, &batches[0], b, cfg.ctx,
                              &ForwardOpts::default());
            kl_divergence(&tlogits[0], &out.logits)
        };
        assert!(
            loss1 < loss0 * 0.95,
            "FT must reduce KL: {loss0:.4} → {loss1:.4} (trace {trace:.2?})"
        );
        // codes must stay frozen
        for q in quants.values() {
            assert!(!q.z.is_empty());
        }
    }
}
