//! Algorithm 4 — FINDOPTIMALRESCALERS: alternating closed-form updates
//! of the diagonal row (T) and column (Γ) rescalers minimizing
//!
//!   J(T,Γ) = (1/an)·tr( WΣ_XWᵀ − 2(WΣ_{X,X̂}+Σ_{Δ,X̂})(TŴ₀Γ)ᵀ
//!                        + TŴ₀ΓΣ_X̂ΓŴ₀ᵀT )
//!
//! with the normalization ‖t‖₁ = a after every alternation.

use crate::linalg::chol::SpdFactor;
use crate::linalg::gemm::{diag_of_product, matmul};
use crate::linalg::Mat;

use super::StatsView;

pub struct RescalerOut {
    pub t: Vec<f64>,
    pub gamma: Vec<f64>,
    /// J after each alternation (tests assert non-increasing)
    pub loss_trace: Vec<f64>,
}

/// Evaluate the objective J(T,Γ).
pub fn objective(
    w0: &Mat,
    w: &Mat,
    stats: StatsView<'_>,
    t: &[f64],
    gamma: &[f64],
) -> f64 {
    let (a, n) = (w.rows, w.cols);
    // TŴ₀Γ
    let mut twg = w0.clone();
    for i in 0..a {
        let row = twg.row_mut(i);
        for j in 0..n {
            row[j] *= t[i] * gamma[j];
        }
    }
    let target = effective_target(w, stats); // WΣ_{X,X̂}+Σ_Δ  (a×n)
    let t1: f64 = {
        let ws = matmul(w, stats.sigma_x);
        diag_of_product(&ws, &w.transpose()).iter().sum()
    };
    let t2: f64 = diag_of_product(&target, &twg.transpose()).iter().sum();
    let t3: f64 = {
        let s = matmul(&twg, stats.sigma_xhat);
        diag_of_product(&s, &twg.transpose()).iter().sum()
    };
    (t1 - 2.0 * t2 + t3) / (a * n) as f64
}

/// (WΣ_{X,X̂} + Σ_{Δ,X̂}) — the drift/residual-corrected regression
/// target appearing in both Alg. 3 and Alg. 4.
pub fn effective_target(w: &Mat, stats: StatsView<'_>) -> Mat {
    let mut tgt = matmul(w, stats.sigma_x_xhat);
    if let Some(d) = stats.sigma_d_xhat {
        tgt = tgt.add(d);
    }
    tgt
}

/// Run the alternating optimization.  `gamma_init` is the LMMSE γ from
/// ZSIC (Alg. 3 line 13).
pub fn find_optimal_rescalers(
    w0: &Mat,
    w: &Mat,
    stats: StatsView<'_>,
    gamma_init: &[f64],
    max_iters: usize,
    ridge: f64,
    tol: f64,
) -> RescalerOut {
    let (a, n) = (w.rows, w.cols);
    let mut t = vec![1.0f64; a];
    let mut gamma = gamma_init.to_vec();
    normalize(&mut t, &mut gamma);

    let target = effective_target(w, stats);
    let mut trace = vec![objective(w0, w, stats, &t, &gamma)];

    // the Γ-step matrix G = Σ_X̂ ∘ (Ŵ₀ᵀT²Ŵ₀) + λI depends only on t
    // (Ŵ₀ and Σ_X̂ are fixed): factor it once per iteration through the
    // blocked Cholesky and reuse the factor for the paired forward/back
    // solves; when t is unchanged between alternations (the update has
    // reached a fixed point) the cached factor is reused outright and
    // the redundant refactorization is dropped.
    let mut g_factor: Option<(Vec<f64>, SpdFactor)> = None;
    for _ in 0..max_iters {
        // ---- Γ-step: γ = (Σ_X̂ ∘ (Ŵ₀ᵀT²Ŵ₀) + λI)⁻¹ diag(Ŵ₀ᵀT·target)
        let stale = g_factor.as_ref().map_or(true, |(t_used, _)| t_used != &t);
        if stale {
            let mut w0t2 = w0.clone(); // rows scaled by t_i²
            for i in 0..a {
                let ti2 = t[i] * t[i];
                w0t2.row_mut(i).iter_mut().for_each(|x| *x *= ti2);
            }
            let f = matmul(&w0.transpose(), &w0t2); // n×n
            let mut g = stats.sigma_xhat.hadamard(&f);
            // adaptive ridge: scale-relative so it is meaningful for any Σ
            let lam = ridge * (g.trace() / n as f64).max(1e-300);
            g.add_diag(lam);
            g_factor = match SpdFactor::new(&g) {
                Ok(fac) => Some((t.clone(), fac)),
                Err(_) => None, // keep previous γ if G is numerically singular
            };
        }
        let mut w0t = w0.clone();
        for i in 0..a {
            let ti = t[i];
            w0t.row_mut(i).iter_mut().for_each(|x| *x *= ti);
        }
        let d = diag_of_product(&w0t.transpose(), &target);
        if let Some((_, fac)) = &g_factor {
            gamma = fac.solve(&d);
        }

        // ---- T-step: t_i = p_i / (q_i + λ)
        let mut w0g = w0.clone(); // cols scaled by γ_j
        for i in 0..a {
            let row = w0g.row_mut(i);
            for j in 0..n {
                row[j] *= gamma[j];
            }
        }
        let p = diag_of_product(&target, &w0g.transpose());
        let s = matmul(&w0g, stats.sigma_xhat);
        let q = diag_of_product(&s, &w0g.transpose());
        let lam_t = ridge * (q.iter().sum::<f64>() / a as f64).max(1e-300);
        for i in 0..a {
            let denom = q[i] + lam_t;
            t[i] = if denom > 0.0 { p[i] / denom } else { 1.0 };
        }

        normalize(&mut t, &mut gamma);
        let loss = objective(w0, w, stats, &t, &gamma);
        let prev = *trace.last().unwrap();
        trace.push(loss);
        if (loss - prev).abs() / (prev.abs() + 1e-12) < tol {
            break;
        }
    }
    RescalerOut {
        t,
        gamma,
        loss_trace: trace,
    }
}

/// Enforce ‖t‖₁ = a, moving the scale into γ (scale invariance of TŴ₀Γ).
fn normalize(t: &mut [f64], gamma: &mut [f64]) {
    let a = t.len() as f64;
    let s = t.iter().map(|x| x.abs()).sum::<f64>() / a;
    if s > 0.0 && s.is_finite() {
        t.iter_mut().for_each(|x| *x /= s);
        gamma.iter_mut().for_each(|x| *x *= s);
    }
}

fn _diag(v: &[f64]) -> Mat {
    Mat::diag_from(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky;
    use crate::linalg::gemm::gram;
    use crate::quant::zsic::{watersic_alphas, zsic};
    use crate::quant::LayerStats;
    use crate::util::rng::Rng;

    fn setup(a: usize, n: usize, c: f64, seed: u64) -> (Mat, Mat, LayerStats, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let mut sigma =
            gram(&Mat::from_fn(2 * n, n, |_, _| rng.gaussian())).scale(1.0 / (2 * n) as f64);
        sigma.add_diag(0.05);
        let l = cholesky(&sigma).unwrap();
        let y = crate::linalg::gemm::matmul(&w, &l);
        let alphas = watersic_alphas(&l, c);
        let out = zsic(&y, &l, &alphas, true, None);
        let mut w0 = Mat::zeros(a, n);
        for i in 0..a {
            for j in 0..n {
                w0[(i, j)] = out.z[i * n + j] as f64 * alphas[j];
            }
        }
        let stats = LayerStats::from_sigma(sigma);
        (w0, w, stats, out.gammas, alphas)
    }

    #[test]
    fn loss_non_increasing() {
        let (w0, w, stats, g0, _) = setup(24, 16, 0.8, 3);
        let out = find_optimal_rescalers(&w0, &w, stats.view(), &g0, 20, 1e-10, 0.0);
        for win in out.loss_trace.windows(2) {
            assert!(
                win[1] <= win[0] + 1e-9 * win[0].abs().max(1.0),
                "loss increased: {:?}",
                out.loss_trace
            );
        }
    }

    #[test]
    fn improves_over_lmmse_initialization() {
        let (w0, w, stats, g0, _) = setup(32, 24, 1.0, 7);
        let t0 = vec![1.0; 32];
        let before = objective(&w0, &w, stats.view(), &t0, &g0);
        let out = find_optimal_rescalers(&w0, &w, stats.view(), &g0, 25, 1e-10, 1e-9);
        let after = objective(&w0, &w, stats.view(), &out.t, &out.gamma);
        assert!(after <= before + 1e-12, "{after} vs {before}");
    }

    #[test]
    fn normalization_holds() {
        let (w0, w, stats, g0, _) = setup(16, 12, 0.6, 9);
        let out = find_optimal_rescalers(&w0, &w, stats.view(), &g0, 10, 1e-10, 0.0);
        let l1: f64 = out.t.iter().map(|x| x.abs()).sum::<f64>() / 16.0;
        assert!((l1 - 1.0).abs() < 1e-9, "‖t‖₁/a = {l1}");
    }

    #[test]
    fn factor_cached_gamma_step_matches_spd_solve_reference() {
        // transcription of the pre-cache alternation: a fresh
        // spd_solve (fresh Cholesky) every iteration — the cached
        // SpdFactor path must be bit-identical
        fn reference(
            w0: &Mat,
            w: &Mat,
            stats: &LayerStats,
            gamma_init: &[f64],
            max_iters: usize,
            ridge: f64,
            tol: f64,
        ) -> (Vec<f64>, Vec<f64>) {
            let (a, n) = (w.rows, w.cols);
            let mut t = vec![1.0f64; a];
            let mut gamma = gamma_init.to_vec();
            super::normalize(&mut t, &mut gamma);
            let target = effective_target(w, stats.view());
            let mut prev = objective(w0, w, stats.view(), &t, &gamma);
            for _ in 0..max_iters {
                let mut w0t2 = w0.clone();
                for i in 0..a {
                    let ti2 = t[i] * t[i];
                    w0t2.row_mut(i).iter_mut().for_each(|x| *x *= ti2);
                }
                let f = crate::linalg::gemm::matmul(&w0.transpose(), &w0t2);
                let mut g = stats.sigma_xhat.hadamard(&f);
                let lam = ridge * (g.trace() / n as f64).max(1e-300);
                g.add_diag(lam);
                let mut w0t = w0.clone();
                for i in 0..a {
                    let ti = t[i];
                    w0t.row_mut(i).iter_mut().for_each(|x| *x *= ti);
                }
                let d = diag_of_product(&w0t.transpose(), &target);
                if let Ok(sol) = crate::linalg::chol::spd_solve(&g, &d) {
                    gamma = sol;
                }
                let mut w0g = w0.clone();
                for i in 0..a {
                    let row = w0g.row_mut(i);
                    for j in 0..n {
                        row[j] *= gamma[j];
                    }
                }
                let p = diag_of_product(&target, &w0g.transpose());
                let s = crate::linalg::gemm::matmul(&w0g, &stats.sigma_xhat);
                let q = diag_of_product(&s, &w0g.transpose());
                let lam_t = ridge * (q.iter().sum::<f64>() / a as f64).max(1e-300);
                for i in 0..a {
                    let denom = q[i] + lam_t;
                    t[i] = if denom > 0.0 { p[i] / denom } else { 1.0 };
                }
                super::normalize(&mut t, &mut gamma);
                let loss = objective(w0, w, stats.view(), &t, &gamma);
                if (loss - prev).abs() / (prev.abs() + 1e-12) < tol {
                    break;
                }
                prev = loss;
            }
            (t, gamma)
        }

        let (w0, w, stats, g0, _) = setup(24, 16, 0.8, 13);
        let out = find_optimal_rescalers(&w0, &w, stats.view(), &g0, 15, 1e-10, 0.0);
        let (t_ref, g_ref) = reference(&w0, &w, &stats, &g0, 15, 1e-10, 0.0);
        assert_eq!(out.t, t_ref, "factor cache changed the T iterates");
        assert_eq!(out.gamma, g_ref, "factor cache changed the Γ iterates");
    }

    #[test]
    fn gamma_step_recovers_known_scaling() {
        // If Ŵ₀ = W·diag(1/s) exactly, the optimal Γ is s (T = 1).
        let mut rng = Rng::new(11);
        let w = Mat::from_fn(20, 8, |_, _| rng.gaussian());
        let s: Vec<f64> = (0..8).map(|j| 0.5 + 0.25 * j as f64).collect();
        let mut w0 = w.clone();
        for i in 0..20 {
            for j in 0..8 {
                w0[(i, j)] /= s[j];
            }
        }
        let mut sigma = gram(&Mat::from_fn(32, 8, |_, _| rng.gaussian())).scale(1.0 / 32.0);
        sigma.add_diag(0.1);
        let stats = LayerStats::from_sigma(sigma);
        let out = find_optimal_rescalers(&w0, &w, stats.view(), &vec![1.0; 8], 30, 1e-12, 1e-12);
        let loss = objective(&w0, &w, stats.view(), &out.t, &out.gamma);
        assert!(loss < 1e-8, "should reach ~exact fit, J = {loss}");
        for j in 0..8 {
            assert!((out.gamma[j] - s[j]).abs() < 1e-4, "γ_{j} = {}", out.gamma[j]);
        }
    }
}
