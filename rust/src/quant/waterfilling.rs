//! Information-theoretic bounds of §3: the reverse-waterfilling
//! rate-distortion function (eq. 2), its high-rate closed form (eq. 3),
//! and the asymptotic gaps of Theorem 3.3 (eqs. 13–14).
//!
//! Rates are in bits (log base 2) throughout, matching the paper's
//! plots.

use crate::linalg::{eig, Mat};

/// ½·log₂(2πe/12) ≈ 0.2546 bit — the integer-lattice shaping gap, the
/// *entire* asymptotic WaterSIC-to-IT-limit gap (eq. 14).
pub const SHAPING_GAP_BITS: f64 = 0.25461433482006296;

fn log2(x: f64) -> f64 {
    x.log2()
}

/// Reverse waterfilling (eq. 2): given water level τ, the (R, D) pair.
fn rd_at_tau(tau: f64, lambdas: &[f64], sigma_w2: f64) -> (f64, f64) {
    let n = lambdas.len() as f64;
    let mut r = 0.0;
    let mut d = 0.0;
    for &lam in lambdas {
        let s = sigma_w2 * lam;
        if s > tau {
            r += 0.5 * log2(s / tau);
            d += tau;
        } else {
            d += s;
        }
    }
    (r / n, d / n)
}

/// R_WF(D, Σ_X): bisect the water level τ to hit distortion `d`.
pub fn r_wf(d: f64, lambdas: &[f64], sigma_w2: f64) -> f64 {
    let dmax: f64 =
        lambdas.iter().map(|&l| sigma_w2 * l).sum::<f64>() / lambdas.len() as f64;
    if d >= dmax {
        return 0.0;
    }
    let (mut lo, mut hi) = (1e-300, sigma_w2 * lambdas.iter().cloned().fold(0.0, f64::max));
    for _ in 0..200 {
        let mid = (lo * hi).sqrt(); // geometric bisection for dynamic range
        let (_, dm) = rd_at_tau(mid, lambdas, sigma_w2);
        if dm < d {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    rd_at_tau((lo * hi).sqrt(), lambdas, sigma_w2).0
}

/// D_WF(R, Σ_X): the distortion-rate function (inverse of r_wf).
pub fn d_wf(r: f64, lambdas: &[f64], sigma_w2: f64) -> f64 {
    if r <= 0.0 {
        return lambdas.iter().map(|&l| sigma_w2 * l).sum::<f64>()
            / lambdas.len() as f64;
    }
    let (mut lo, mut hi) = (1e-300, sigma_w2 * lambdas.iter().cloned().fold(0.0, f64::max));
    for _ in 0..200 {
        let mid = (lo * hi).sqrt();
        let (rm, _) = rd_at_tau(mid, lambdas, sigma_w2);
        if rm > r {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    rd_at_tau((lo * hi).sqrt(), lambdas, sigma_w2).1
}

/// High-rate form (eq. 3): R = ½ log₂(σ_W²·|Σ|^{1/n} / D), valid for
/// D < min σ_W²λ_i.
pub fn r_high_rate(d: f64, lambdas: &[f64], sigma_w2: f64) -> f64 {
    let mean_log: f64 =
        lambdas.iter().map(|&l| l.ln()).sum::<f64>() / lambdas.len() as f64;
    0.5 * log2(sigma_w2 * mean_log.exp() / d)
}

/// Eigenvalues of a covariance matrix (descending) — convenience entry.
pub fn spectrum(sigma: &Mat) -> Vec<f64> {
    eig::eigvals(sigma)
        .into_iter()
        .map(|x| x.max(1e-300))
        .collect()
}

/// Asymptotic GPTQ gap to waterfilling (eq. 13), given the Cholesky
/// diagonal ℓ_ii of Σ_X: shaping gap + ½ log₂(AM/GM of ℓ_ii²).
pub fn gptq_gap_bits(l_diag: &[f64]) -> f64 {
    SHAPING_GAP_BITS + amgm_gap_bits(l_diag)
}

/// ½ log₂( (1/n Σ ℓ_ii²) / (Π ℓ_ii²)^{1/n} ) ≥ 0 — the AM/GM spread term
/// that WaterSIC's spacing rule eliminates.
pub fn amgm_gap_bits(l_diag: &[f64]) -> f64 {
    let n = l_diag.len() as f64;
    let am: f64 = l_diag.iter().map(|&x| x * x).sum::<f64>() / n;
    let log_gm: f64 =
        l_diag.iter().map(|&x| (x * x).ln()).sum::<f64>() / n;
    0.5 * log2(am / log_gm.exp())
}

/// Asymptotic WaterSIC gap to waterfilling (eq. 14): the shaping gap,
/// independent of Σ_X.
pub fn watersic_gap_bits(_l_diag: &[f64]) -> f64 {
    SHAPING_GAP_BITS
}

/// AR(1) covariance Σ_ij = ρ^{|i−j|} — the standard stress family used
/// by the `repro theory` experiment (strong conditioning as ρ→1).
pub fn ar1_sigma(n: usize, rho: f64) -> Mat {
    Mat::from_fn(n, n, |i, j| rho.powi((i as i32 - j as i32).abs()))
}

/// "Spiked" covariance: identity plus k strong random directions —
/// models the PCA concentration of real activations.
pub fn spiked_sigma(n: usize, k: usize, strength: f64, seed: u64) -> Mat {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut sigma = Mat::eye(n);
    for _ in 0..k {
        let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        v.iter_mut().for_each(|x| *x /= norm);
        for i in 0..n {
            for j in 0..n {
                sigma[(i, j)] += strength * v[i] * v[j];
            }
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky;

    #[test]
    fn shaping_gap_constant() {
        let expect = 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E / 12.0).log2();
        assert!((SHAPING_GAP_BITS - expect).abs() < 1e-12);
        assert!((SHAPING_GAP_BITS - 0.255).abs() < 5e-4); // paper's 0.255
    }

    #[test]
    fn white_source_matches_shannon() {
        // Σ = I: R(D) = ½log₂(σ²/D)
        let lambdas = vec![1.0; 64];
        for d in [0.5, 0.1, 0.01] {
            let r = r_wf(d, &lambdas, 1.0);
            assert!((r - 0.5 * (1.0f64 / d).log2()).abs() < 1e-6, "d={d}");
        }
    }

    #[test]
    fn wf_and_inverse_consistent() {
        let sigma = ar1_sigma(32, 0.9);
        let lam = spectrum(&sigma);
        for r in [0.5, 1.0, 2.0, 4.0] {
            let d = d_wf(r, &lam, 1.0);
            let r2 = r_wf(d, &lam, 1.0);
            assert!((r - r2).abs() < 1e-4, "r={r} r2={r2}");
        }
    }

    #[test]
    fn high_rate_form_matches_wf_at_low_distortion() {
        let sigma = ar1_sigma(24, 0.8);
        let lam = spectrum(&sigma);
        let dmin = lam.iter().cloned().fold(f64::INFINITY, f64::min);
        let d = dmin * 0.1;
        let r1 = r_wf(d, &lam, 1.0);
        let r2 = r_high_rate(d, &lam, 1.0);
        assert!((r1 - r2).abs() < 1e-6, "{r1} vs {r2}");
    }

    #[test]
    fn high_rate_form_is_a_lower_bound() {
        // the high-rate expression is the Shannon lower bound: R_WF ≥ it
        // everywhere, with equality only below the min eigenvalue
        let sigma = ar1_sigma(24, 0.95);
        let lam = spectrum(&sigma);
        for d in [1e-4, 1e-2, 0.2] {
            assert!(
                r_wf(d, &lam, 1.0) >= r_high_rate(d, &lam, 1.0) - 1e-9,
                "d={d}"
            );
        }
        // strictly above once D exceeds the smallest eigenvalue
        let d = lam.iter().sum::<f64>() / 48.0;
        assert!(r_wf(d, &lam, 1.0) > r_high_rate(d, &lam, 1.0) + 1e-6);
    }

    #[test]
    fn gptq_gap_grows_with_conditioning() {
        // the paper's headline negative result: GPTQ's gap is unbounded
        let mut prev = 0.0;
        for rho in [0.0, 0.5, 0.9, 0.99] {
            let sigma = ar1_sigma(48, rho);
            let l = cholesky(&sigma).unwrap();
            let gap = gptq_gap_bits(&l.diag());
            assert!(gap >= prev - 1e-12, "rho={rho}: {gap} < {prev}");
            prev = gap;
            // WaterSIC's gap is constant
            assert!((watersic_gap_bits(&l.diag()) - SHAPING_GAP_BITS).abs() < 1e-15);
        }
        assert!(prev > 0.5, "gap at rho=0.99 should exceed 0.5 bit: {prev}");
    }

    #[test]
    fn amgm_zero_for_white() {
        let l = cholesky(&Mat::eye(16)).unwrap();
        assert!(amgm_gap_bits(&l.diag()).abs() < 1e-12);
    }

    #[test]
    fn rotation_invariance_of_watersic_bound() {
        // |Σ| is rotation invariant → D*_WaterSIC unchanged under UΣUᵀ;
        // verify via spectrum (rotation = same eigenvalues)
        let sigma = spiked_sigma(16, 3, 10.0, 5);
        let lam = spectrum(&sigma);
        let r1 = r_high_rate(0.001, &lam, 1.0);
        // "rotate" = reuse eigenvalues in different order
        let mut lam2 = lam.clone();
        lam2.reverse();
        let r2 = r_high_rate(0.001, &lam2, 1.0);
        assert!((r1 - r2).abs() < 1e-12);
    }
}
