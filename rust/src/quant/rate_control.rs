//! Rate targeting (§4 "Rate assignment"): the final entropy is a
//! monotone, approximately unit-slope function of −log₂ c, so a secant
//! iteration on log₂ c converges to < 0.005 bit in 2–3 steps.  Row
//! subsampling for cheap evaluations is the caller's concern (the
//! coordinator passes a subsampled closure, then re-runs full).

/// Generic secant search: find `scale` such that `rate_of(scale) ≈
/// target`, exploiting rate ≈ K − log₂(scale).  Returns the best scale
/// found.  `rate_of` must be monotone decreasing in scale.
///
/// A non-finite evaluation (a probe whose factorization failed and was
/// reported as NaN) aborts the search immediately: the iteration falls
/// back to the best finite probe seen so far — `scale0` when none —
/// instead of feeding NaN through the secant update and burning the
/// remaining iterations on NaN arithmetic.
pub fn secant_scale(
    rate_of: impl Fn(f64) -> f64,
    scale0: f64,
    target: f64,
    tol_bits: f64,
    max_iter: usize,
) -> f64 {
    // work in u = log2(scale); model rate(u) ≈ K − u
    let mut u0 = scale0.log2();
    let mut r0 = rate_of(scale0);
    if !r0.is_finite() {
        return scale0;
    }
    if (r0 - target).abs() < tol_bits {
        return scale0;
    }
    // unit-slope first correction
    let mut u1 = u0 + (r0 - target);
    let mut best = (r0, u0);
    for _ in 0..max_iter {
        let r1 = rate_of(2f64.powf(u1));
        if !r1.is_finite() {
            return 2f64.powf(best.1);
        }
        if (r1 - target).abs() < (best.0 - target).abs() {
            best = (r1, u1);
        }
        if (r1 - target).abs() < tol_bits {
            return 2f64.powf(u1);
        }
        let denom = r1 - r0;
        let step = if denom.abs() > 1e-9 {
            (target - r1) * (u1 - u0) / denom
        } else {
            r1 - target // fall back to unit slope
        };
        u0 = u1;
        r0 = r1;
        u1 += step.clamp(-8.0, 8.0);
    }
    2f64.powf(best.1)
}

/// Running global rate budget (§4 / Appendix D): layers are quantized
/// sequentially; each layer is assigned the remaining budget spread over
/// the remaining parameters, and its *achieved* bits are charged back —
/// so savings (e.g. dead features) flow to later layers.
#[derive(Clone, Debug)]
pub struct RateBudget {
    total_bits: f64,
    spent_bits: f64,
    remaining_params: f64,
}

impl RateBudget {
    /// `target_rate` bits/param over `total_params` parameters.
    pub fn new(target_rate: f64, total_params: usize) -> Self {
        RateBudget {
            total_bits: target_rate * total_params as f64,
            spent_bits: 0.0,
            remaining_params: total_params as f64,
        }
    }

    /// Floor of any assignment (entropy-coded layers can always land
    /// this low) and ceiling (nothing needs more than an f32 per
    /// weight) — `assign` clamps into this range so a params-count
    /// mismatch can never leak `inf`/`NaN` into the secant target.
    pub const MIN_RATE: f64 = 0.05;
    pub const MAX_RATE: f64 = 32.0;

    /// Rate to assign to the next layer of `params` parameters.
    ///
    /// Once the charged params reach (or exceed) `total_params` the
    /// denominator is 0 or negative — dividing yields ±inf, or NaN when
    /// the budget is simultaneously exhausted — so any further
    /// assignment falls back to the floor instead.
    pub fn assign(&self, _params: usize) -> f64 {
        if self.remaining_params <= 0.0 {
            return Self::MIN_RATE;
        }
        let rate = (self.total_bits - self.spent_bits) / self.remaining_params;
        if rate.is_finite() {
            rate.clamp(Self::MIN_RATE, Self::MAX_RATE)
        } else {
            Self::MIN_RATE
        }
    }

    /// Charge the achieved rate of a finished layer.
    pub fn charge(&mut self, params: usize, achieved_rate: f64) {
        self.spent_bits += achieved_rate * params as f64;
        self.remaining_params -= params as f64;
    }

    /// Average rate actually spent so far.
    pub fn spent_average(&self, total_params: usize) -> f64 {
        self.spent_bits / total_params as f64
    }

    pub fn done(&self) -> bool {
        self.remaining_params <= 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secant_converges_on_ideal_model() {
        // rate(c) = 5 − log2(c) exactly
        let rate = |c: f64| 5.0 - c.log2();
        let c = secant_scale(rate, 1.0, 2.0, 0.001, 10);
        assert!((rate(c) - 2.0).abs() < 0.001, "rate {}", rate(c));
    }

    #[test]
    fn secant_converges_on_distorted_model() {
        // slope 0.8 with curvature — still converges via secant
        let rate = |c: f64| 4.0 - 0.8 * c.log2() + 0.05 * c.log2().sin();
        let c = secant_scale(rate, 0.5, 2.5, 0.005, 20);
        assert!((rate(c) - 2.5).abs() < 0.005);
    }

    #[test]
    fn secant_bails_out_on_first_non_finite_evaluation() {
        // regression: rate_of swallowing a factorization error into NaN
        // used to let the secant iterate on NaN for all max_iter steps;
        // it must now stop at the first non-finite probe and fall back
        use std::cell::Cell;
        // every evaluation is NaN → exactly one probe, returns scale0
        let evals = Cell::new(0usize);
        let c = secant_scale(
            |_| {
                evals.set(evals.get() + 1);
                f64::NAN
            },
            0.75,
            2.0,
            0.005,
            10,
        );
        assert_eq!(c, 0.75, "must fall back to the initial scale");
        assert_eq!(evals.get(), 1, "must not keep probing on NaN");
        // finite first probe, NaN after → two probes, best-so-far (= c0)
        let evals = Cell::new(0usize);
        let c = secant_scale(
            |s| {
                evals.set(evals.get() + 1);
                if evals.get() == 1 {
                    5.0 - s.log2()
                } else {
                    f64::NAN
                }
            },
            1.0,
            2.0,
            0.005,
            10,
        );
        assert_eq!(c, 1.0);
        assert_eq!(evals.get(), 2);
        assert!(c.is_finite());
    }

    #[test]
    fn budget_redistribution() {
        let mut b = RateBudget::new(2.0, 1000);
        assert!((b.assign(100) - 2.0).abs() < 1e-12);
        // first layer comes in cheap (dead features) → later layers get more
        b.charge(500, 1.5);
        let next = b.assign(100);
        assert!(next > 2.0, "saved bits must be redistributed: {next}");
        b.charge(500, next);
        assert!(b.done());
        assert!((b.spent_average(1000) - (0.5 * 1.5 + 0.5 * next)).abs() < 1e-9);
    }

    #[test]
    fn budget_floor() {
        let mut b = RateBudget::new(1.0, 100);
        b.charge(50, 10.0); // overspend
        assert!(b.assign(10) >= 0.05);
    }

    #[test]
    fn assign_is_finite_when_charged_past_total_params() {
        // regression: a params-count mismatch (charging more params
        // than the budget was built for) drove remaining_params to 0
        // and then negative — assign returned inf (or a spuriously huge
        // negative-over-negative rate) and fed it to the secant
        let mut b = RateBudget::new(2.0, 100);
        b.charge(100, 1.0); // budget exactly exhausted: remaining = 0
        let r = b.assign(10);
        assert!(r.is_finite(), "assign must stay finite at 0 remaining: {r}");
        assert_eq!(r, RateBudget::MIN_RATE);
        b.charge(50, 1.0); // past total_params: remaining < 0
        let r = b.assign(10);
        assert!(r.is_finite(), "assign must stay finite past total: {r}");
        assert_eq!(r, RateBudget::MIN_RATE);
        // an under-spent budget over few remaining params is capped
        let mut b = RateBudget::new(8.0, 1000);
        b.charge(990, 0.05);
        let r = b.assign(10);
        assert!(r <= RateBudget::MAX_RATE, "assignment must be capped: {r}");
        assert!(r >= RateBudget::MIN_RATE);
    }
}
