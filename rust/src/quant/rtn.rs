//! Round-to-nearest baselines: classical absmax RTN (log-cardinality
//! rates) and entropy-coded Huffman-RTN (ε-grid + entropy coding), as
//! compared against in Table 2.

use crate::linalg::Mat;
use crate::util::round_ties_even;

use super::LayerQuant;

/// Classical RTN at `bits` with per-row absmax scaling: each row is
/// mapped to the symmetric integer grid {−(2^{b−1}−1) … 2^{b−1}−1}.
/// Reported rate is log-cardinality = `bits` (+ scale overhead).
pub fn rtn_absmax(w: &Mat, bits: u32) -> LayerQuant {
    let (a, n) = (w.rows, w.cols);
    let qmax = ((1i64 << (bits - 1)) - 1).max(1) as f64;
    let mut z = vec![0i32; a * n];
    let mut t = vec![1.0; a];
    for i in 0..a {
        let absmax = w.row(i).iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
        t[i] = scale;
        for j in 0..n {
            z[i * n + j] = round_ties_even(w[(i, j)] / scale) as i32;
        }
    }
    let entropy = crate::entropy::entropy_bits(&z);
    LayerQuant {
        a,
        n,
        z,
        alphas: vec![1.0; n],
        gammas: vec![1.0; n],
        t,
        entropy_bits: entropy,
        rate_bits: bits as f64 + 16.0 / n as f64,
        dead_cols: vec![],
    }
}

/// Huffman-RTN: uniform ε-grid over the whole matrix, entropy-coded.
/// `eps` is the grid spacing; rate is the empirical entropy.
pub fn rtn_grid(w: &Mat, eps: f64) -> LayerQuant {
    let (a, n) = (w.rows, w.cols);
    let mut z = vec![0i32; a * n];
    for i in 0..a {
        for j in 0..n {
            z[i * n + j] = round_ties_even(w[(i, j)] / eps) as i32;
        }
    }
    let entropy = crate::entropy::entropy_bits(&z);
    LayerQuant {
        a,
        n,
        z,
        alphas: vec![eps; n],
        gammas: vec![1.0; n],
        t: vec![1.0; a],
        entropy_bits: entropy,
        rate_bits: entropy + 16.0 / n as f64,
        dead_cols: vec![],
    }
}

/// Find the ε hitting a target entropy rate via the same secant scheme
/// as WaterSIC (rate ≈ const − log₂ ε).
pub fn rtn_grid_at_rate(w: &Mat, target_bits: f64) -> LayerQuant {
    let sd = {
        let m = w.data.iter().sum::<f64>() / w.data.len() as f64;
        (w.data.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / w.data.len() as f64)
            .sqrt()
    };
    let rate_of = |eps: f64| rtn_grid(w, eps).entropy_bits;
    let eps0 = sd * (2.0f64 * std::f64::consts::PI * std::f64::consts::E).sqrt()
        / 2.0f64.powf(target_bits);
    let eps = super::rate_control::secant_scale(rate_of, eps0, target_bits, 0.005, 12);
    rtn_grid(w, eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_w(a: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(a, n, |_, _| rng.gaussian())
    }

    #[test]
    fn rtn_absmax_reconstruction_error_bounded() {
        let w = gaussian_w(32, 32, 1);
        let q = rtn_absmax(&w, 4);
        let wh = q.dequant();
        for i in 0..32 {
            let absmax = w.row(i).iter().fold(0.0f64, |m, x| m.max(x.abs()));
            let step = absmax / 7.0;
            for j in 0..32 {
                assert!(
                    (w[(i, j)] - wh[(i, j)]).abs() <= 0.5 * step + 1e-12,
                    "({i},{j})"
                );
            }
        }
        assert!(q.z.iter().all(|&z| z.abs() <= 7));
    }

    #[test]
    fn rtn_grid_entropy_decreases_with_eps() {
        let w = gaussian_w(64, 64, 2);
        let fine = rtn_grid(&w, 0.05).entropy_bits;
        let coarse = rtn_grid(&w, 0.5).entropy_bits;
        assert!(fine > coarse);
    }

    #[test]
    fn rtn_rate_targeting() {
        let w = gaussian_w(128, 64, 3);
        for target in [2.0, 3.0, 4.0] {
            let q = rtn_grid_at_rate(&w, target);
            assert!(
                (q.entropy_bits - target).abs() < 0.05,
                "target {target}, got {}",
                q.entropy_bits
            );
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let w = gaussian_w(16, 48, 4);
        let e = |bits| {
            let q = rtn_absmax(&w, bits);
            let wh = q.dequant();
            w.sub(&wh).frob_norm()
        };
        assert!(e(8) < e(4));
        assert!(e(4) < e(2));
    }
}
