//! Quantization core: ZSIC (Alg. 1), RTN, GPTQ, PlainWaterSIC (Alg. 2),
//! full WaterSIC (Alg. 3 + Alg. 4), the waterfilling information-theoretic
//! bounds of §3, rate targeting, and adaptive mixing.

pub mod gptq;
pub mod mixing;
pub mod rate_control;
pub mod rescalers;
pub mod rtn;
pub mod watersic;
pub mod waterfilling;
pub mod zsic;

pub use gptq::{PreparedGptq, PreparedGptqStats};
pub use watersic::{PreparedLayer, PreparedStats};

use crate::linalg::{gemm, Mat};

/// Result of quantizing one linear layer W (a × n).
#[derive(Clone, Debug)]
pub struct LayerQuant {
    pub a: usize,
    pub n: usize,
    /// integer codes, row-major a×n
    pub z: Vec<i32>,
    /// per-column grid spacings α_i (diagonal of A)
    pub alphas: Vec<f64>,
    /// per-column rescalers Γ (LMMSE γ fused with the Alg. 4 Γ-step)
    pub gammas: Vec<f64>,
    /// per-row rescalers T (all-ones unless Alg. 4 ran)
    pub t: Vec<f64>,
    /// joint empirical entropy of the codes, bits/weight
    pub entropy_bits: f64,
    /// effective rate R_eff = H + 16/a + 16/n (Alg. 3 Phase 3: BF16 row
    /// rescaler overhead + fused column scale overhead)
    pub rate_bits: f64,
    /// columns zeroed by dead-feature erasure (original indices)
    pub dead_cols: Vec<usize>,
}

impl LayerQuant {
    /// Ŵ = T · Z · diag(γ_i α_i)
    pub fn dequant(&self) -> Mat {
        let mut w = Mat::zeros(self.a, self.n);
        for i in 0..self.a {
            let ti = self.t[i];
            let row = w.row_mut(i);
            for j in 0..self.n {
                row[j] = ti
                    * self.z[i * self.n + j] as f64
                    * self.gammas[j]
                    * self.alphas[j];
            }
        }
        w
    }

    /// Per-column entropies (Fig. 5 diagnostics).
    pub fn column_entropies(&self) -> Vec<f64> {
        crate::entropy::column_entropies(&self.z, self.a, self.n)
    }
}

/// Layerwise distortion D = tr((W−Ŵ) Σ (W−Ŵ)ᵀ) / (n·a)  (eq. 1).
pub fn distortion(w: &Mat, w_hat: &Mat, sigma: &Mat) -> f64 {
    let d = w.sub(w_hat);
    let ds = gemm::matmul(&d, sigma);
    let tr: f64 = gemm::diag_of_product(&ds, &d.transpose()).iter().sum();
    tr / (w.rows * w.cols) as f64
}

/// Relative distortion D / (tr(W Σ Wᵀ)/(n·a)) — the "relative MSE" of the
/// ablation figures.
pub fn relative_distortion(w: &Mat, w_hat: &Mat, sigma: &Mat) -> f64 {
    let num = distortion(w, w_hat, sigma);
    let ws = gemm::matmul(w, sigma);
    let den: f64 = gemm::diag_of_product(&ws, &w.transpose()).iter().sum();
    num / (den / (w.rows * w.cols) as f64).max(1e-300)
}

/// Calibration statistics for one layer, all estimated by the
/// coordinator from teacher/student activations (§4):
///   Σ_X        teacher input covariance (n×n)
///   Σ_X̂        student (quantized-prefix) input covariance (n×n)
///   Σ_{X,X̂}    cross covariance (n×n)
///   Σ_{Δ,X̂}    residual-drift cross term E[(R−R̂)X̂ᵀ] (a×n), zero unless
///              the layer feeds the residual stream (w_o, w_2)
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub sigma_x: Mat,
    pub sigma_xhat: Mat,
    pub sigma_x_xhat: Mat,
    pub sigma_d_xhat: Option<Mat>,
}

impl LayerStats {
    /// The no-drift-information special case: Σ_X̂ = Σ_{X,X̂} = Σ_X.
    pub fn from_sigma(sigma_x: Mat) -> LayerStats {
        LayerStats {
            sigma_xhat: sigma_x.clone(),
            sigma_x_xhat: sigma_x.clone(),
            sigma_x,
            sigma_d_xhat: None,
        }
    }

    pub fn n(&self) -> usize {
        self.sigma_x.rows
    }

    /// Borrow every field as a [`StatsView`].
    pub fn view(&self) -> StatsView<'_> {
        StatsView {
            sigma_x: &self.sigma_x,
            sigma_xhat: &self.sigma_xhat,
            sigma_x_xhat: &self.sigma_x_xhat,
            sigma_d_xhat: self.sigma_d_xhat.as_ref(),
        }
    }
}

/// Borrowed view of [`LayerStats`].  The shared-stats front-end
/// ([`watersic::PreparedStats`]) lends its live-restricted covariances
/// to the target solve and the Alg. 4 rescaler optimization through
/// this view instead of cloning them per system — the drift term can
/// point at a per-system row slice while the n×n covariances stay
/// shared.
#[derive(Clone, Copy)]
pub struct StatsView<'a> {
    pub sigma_x: &'a Mat,
    pub sigma_xhat: &'a Mat,
    pub sigma_x_xhat: &'a Mat,
    pub sigma_d_xhat: Option<&'a Mat>,
}

/// Common tuning knobs of the practical pipeline (defaults follow
/// Appendix D: tiny damping with dead-feature erasure enabled).
#[derive(Clone, Debug)]
pub struct QuantOpts {
    /// apply the LMMSE per-column shrinkage γ_i (eq. 15)
    pub lmmse: bool,
    /// run the Alg. 4 alternating T/Γ optimization
    pub rescalers: bool,
    /// relative Hessian damping δ (δ·mean(diag) added to Σ_X̂)
    pub damping: f64,
    /// dead-feature threshold τ ([Σ_X]_ii < τ·median → erase)
    pub dead_tau: f64,
    /// max Alg. 4 alternations
    pub rescaler_iters: usize,
    /// ridge λ inside Alg. 4
    pub rescaler_ridge: f64,
}

impl Default for QuantOpts {
    fn default() -> Self {
        QuantOpts {
            lmmse: true,
            rescalers: true,
            // Appendix D uses δ=1e-4 with ~2.4M calibration tokens; our
            // picollama calibration sets are ~1–2k tokens, so Σ̂ is far
            // noisier and needs a stronger ridge (validated by the
            // `watersic sweep` ablation: 0.01 ≈ PPL-optimal here).
            damping: 1e-2,
            dead_tau: 1e-3,
            rescaler_iters: 25,
            rescaler_ridge: 1e-10,
        }
    }
}

impl QuantOpts {
    /// GPTQ-paper defaults: heavy damping, no LMMSE, no rescalers, no
    /// dead-feature erasure.
    pub fn gptq() -> Self {
        QuantOpts {
            lmmse: false,
            rescalers: false,
            damping: 0.1,
            dead_tau: 0.0,
            rescaler_iters: 0,
            rescaler_ridge: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_applies_all_scales() {
        let lq = LayerQuant {
            a: 2,
            n: 2,
            z: vec![1, 2, -1, 0],
            alphas: vec![0.5, 2.0],
            gammas: vec![1.0, 0.5],
            t: vec![1.0, 2.0],
            entropy_bits: 0.0,
            rate_bits: 0.0,
            dead_cols: vec![],
        };
        let w = lq.dequant();
        assert_eq!(w.data, vec![0.5, 2.0, -1.0, 0.0]);
    }

    #[test]
    fn distortion_zero_for_exact() {
        let w = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let sigma = Mat::eye(2);
        assert_eq!(distortion(&w, &w, &sigma), 0.0);
        assert_eq!(relative_distortion(&w, &w, &sigma), 0.0);
    }

    #[test]
    fn distortion_identity_sigma_is_mse() {
        let w = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        let wh = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let d = distortion(&w, &wh, &Mat::eye(2));
        assert!((d - 1.0).abs() < 1e-12); // (1+1)/2
    }
}
