//! Rust-native ZSIC (Algorithm 1) with optional LMMSE correction — the
//! L3 twin of the Pallas kernel, used for arbitrary shapes, for the
//! theory experiments, and as the fallback when PJRT artifacts are not
//! built.  Matches `kernels/ref.py` (and therefore the Pallas kernel)
//! exactly: f64 accumulation, round-half-to-even.
//!
//! Hot path: the per-column interference update is restricted to the
//! columns left of i (L is lower-triangular, so columns right of i see
//! zeros; column i's own residual is tracked separately), giving the
//! GPTQ-standard O(a·n²/2) flop count, row-parallelized across threads.

use crate::linalg::Mat;
use crate::util::round_ties_even;
use crate::util::threadpool::{default_threads, parallel_ranges};

/// Output of one ZSIC run.
pub struct ZsicOut {
    /// integer codes, row-major a×n
    pub z: Vec<i32>,
    /// LMMSE shrinkage per column (ones when disabled)
    pub gammas: Vec<f64>,
    /// final residual panel; column i = quantization error e_SIC of col i
    pub resid: Mat,
}

/// Run ZSIC on Y = W·L (or the drift-corrected ŷ).
///
/// * `y`: (a, n); `l`: (n, n) lower-triangular; `alphas`: (n,)
/// * `lmmse`: per-column shrinkage γ_i (eq. 15); the recursive update
///   uses the γ-corrected value as required by §4.
/// * `clamp`: optional symmetric clamp |z| ≤ clamp (GPTQ `maxq` mode —
///   log-cardinality rates; `None` for entropy-coded operation).
pub fn zsic(y: &Mat, l: &Mat, alphas: &[f64], lmmse: bool, clamp: Option<i32>) -> ZsicOut {
    let (a, n) = (y.rows, y.cols);
    assert_eq!(l.rows, n);
    assert_eq!(l.cols, n);
    assert_eq!(alphas.len(), n);

    let mut yw = y.clone();
    let mut z = vec![0i32; a * n];
    let mut gammas = vec![1.0f64; n];
    let threads = if a * n > 1 << 14 { default_threads() } else { 1 };

    // GPTQ-style column blocking (§Perf): inside a block the
    // interference update is applied immediately (those columns are read
    // next); the update of everything left of the block is deferred and
    // applied once per block as a rank-B panel product — the residual
    // panel is traversed n/B times instead of n times.  The deferred
    // contributions are linear and the left columns are not read in
    // between, so the recursion is exact; large blocks route the panel
    // product through the packed gemm (same sums reassociated, ≲1e-15
    // relative to the unblocked recursion), small blocks keep the
    // serial axpy order and stay bit-identical to it.
    const BLOCK: usize = 64;
    let mut bhi = n;
    // per-block scaled codes s_{r,k} = γ_k α_k z_{r,k}
    let mut scaled = vec![0.0f64; a * BLOCK];
    while bhi > 0 {
        let blo = bhi.saturating_sub(BLOCK);
        let bw = bhi - blo;
        for i in (blo..bhi).rev() {
            let s = alphas[i] * l[(i, i)];
            debug_assert!(s != 0.0, "zero spacing at column {i}");
            // quantize column i
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for r in 0..a {
                let v = yw[(r, i)];
                let mut zi = round_ties_even(v / s);
                if let Some(c) = clamp {
                    zi = zi.clamp(-(c as f64), c as f64);
                }
                z[r * n + i] = zi as i32;
                num += v * zi;
                den += zi * zi;
            }
            if lmmse && den > 0.0 {
                gammas[i] = num / (s * den);
            }
            let g_alpha = gammas[i] * alphas[i];
            let lrow = l.row(i);
            // immediate update of the in-block columns blo..=i (column i
            // becomes its residual; columns > i have L[i, j>i] = 0)
            for r in 0..a {
                let zi = z[r * n + i] as f64;
                let coeff = g_alpha * zi;
                scaled[r * BLOCK + (i - blo)] = coeff;
                if zi == 0.0 {
                    continue;
                }
                let row = yw.row_mut(r);
                for j in blo..=i {
                    row[j] -= coeff * lrow[j];
                }
            }
        }
        // deferred rank-bw panel update of columns 0..blo:
        //   yw[:, :blo] -= scaled · L[blo..bhi, :blo]
        if blo > 0 {
            if a * bw * blo > 1 << 14 {
                // fused packed panel product (α = −1) instead of bw
                // separate axpy sweeps over the residual panel
                crate::linalg::gemm::gemm_acc_strided(
                    a,
                    bw,
                    blo,
                    &scaled,
                    BLOCK,
                    &l.data[blo * n..],
                    n,
                    &mut yw.data,
                    n,
                    -1.0,
                    threads,
                );
            } else {
                // small blocks: keep the serial axpy order, which is
                // bit-identical to the unblocked reference recursion
                let ywp = std::sync::atomic::AtomicPtr::new(yw.data.as_mut_ptr());
                let scaled_ref = &scaled;
                parallel_ranges(a, threads, |range| {
                    let p = ywp.load(std::sync::atomic::Ordering::Relaxed);
                    for r in range {
                        // check-aliasing: residual row r is this
                        // task's exclusive write-set
                        crate::util::aliasing::claim(
                            p.wrapping_add(r * n) as *const f64,
                            blo,
                        );
                        // SAFETY: disjoint row ranges per thread.
                        let row = unsafe {
                            std::slice::from_raw_parts_mut(p.add(r * n), blo)
                        };
                        for k in 0..bw {
                            let coeff = scaled_ref[r * BLOCK + k];
                            if coeff == 0.0 {
                                continue;
                            }
                            let lrow = l.row(blo + k);
                            for j in 0..blo {
                                row[j] -= coeff * lrow[j];
                            }
                        }
                    }
                });
            }
        }
        bhi = blo;
    }
    ZsicOut {
        z,
        gammas,
        resid: yw,
    }
}

/// WaterSIC spacing rule (eq. 12) with |A|^{1/n} = αⁿ normalization:
/// α_i = c/ℓ_ii with c = α·|L|^{1/n}.
pub fn watersic_alphas(l: &Mat, c: f64) -> Vec<f64> {
    watersic_alphas_from_diag(&l.diag(), c)
}

/// [`watersic_alphas`] from a pre-extracted Cholesky diagonal — the
/// `PreparedLayer` cache stores ℓ_ii once (the α-direction) and
/// re-derives the spacings per secant probe through the exact same
/// `c / ℓ_ii` arithmetic, so cached and uncached runs are bit-identical.
pub fn watersic_alphas_from_diag(diag: &[f64], c: f64) -> Vec<f64> {
    diag.iter().map(|&d| c / d.abs()).collect()
}

/// GPTQ spacing rule: A = αI.
pub fn gptq_alphas(n: usize, alpha: f64) -> Vec<f64> {
    vec![alpha; n]
}

/// Geometric mean of the Cholesky diagonal = |Σ|^{1/2n}; used to convert
/// a normalized point density α into the WaterSIC constant c.
pub fn geomean_diag(l: &Mat) -> f64 {
    let d = l.diag();
    (d.iter().map(|x| x.abs().ln()).sum::<f64>() / d.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky;
    use crate::linalg::gemm::{gram, matmul};
    use crate::util::rng::Rng;

    pub(crate) fn problem(
        a: usize,
        n: usize,
        seed: u64,
    ) -> (Mat, Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let mut sigma =
            gram(&Mat::from_fn(2 * n, n, |_, _| rng.gaussian())).scale(1.0 / (2 * n) as f64);
        sigma.add_diag(0.05);
        let l = cholesky(&sigma).unwrap();
        let y = matmul(&w, &l);
        (w, sigma, l, y)
    }

    /// Literal transcription of ref_zsic (full-width update, serial).
    fn reference(y: &Mat, l: &Mat, alphas: &[f64], lmmse: bool) -> (Vec<i32>, Vec<f64>, Mat) {
        let (a, n) = (y.rows, y.cols);
        let mut yw = y.clone();
        let mut z = vec![0i32; a * n];
        let mut g = vec![1.0; n];
        for i in (0..n).rev() {
            let s = alphas[i] * l[(i, i)];
            let mut num = 0.0;
            let mut den = 0.0;
            for r in 0..a {
                let zi = round_ties_even(yw[(r, i)] / s);
                z[r * n + i] = zi as i32;
                num += yw[(r, i)] * zi;
                den += zi * zi;
            }
            if lmmse && den > 0.0 {
                g[i] = num / (s * den);
            }
            for r in 0..a {
                let coeff = g[i] * alphas[i] * z[r * n + i] as f64;
                for j in 0..n {
                    yw[(r, j)] -= coeff * l[(i, j)];
                }
            }
        }
        (z, g, yw)
    }

    #[test]
    fn matches_reference_impl() {
        for (a, n, lmmse) in [(16, 24, false), (16, 24, true), (40, 33, true)] {
            let (_, _, l, y) = problem(a, n, (a + n) as u64);
            let alphas = watersic_alphas(&l, 0.3);
            let out = zsic(&y, &l, &alphas, lmmse, None);
            let (z0, g0, r0) = reference(&y, &l, &alphas, lmmse);
            assert_eq!(out.z, z0);
            for i in 0..n {
                assert!((out.gammas[i] - g0[i]).abs() < 1e-12);
            }
            assert!(out.resid.sub(&r0).max_abs() < 1e-9);
        }
    }

    #[test]
    fn lemma_3_2_error_in_cube() {
        // property sweep: e_SIC ∈ CUBE·A·diag(L) for many random draws
        for seed in 0..8u64 {
            let (_, _, l, y) = problem(12, 20, 100 + seed);
            let c = 0.1 + 0.2 * seed as f64;
            let alphas = watersic_alphas(&l, c);
            let out = zsic(&y, &l, &alphas, false, None);
            for i in 0..12 {
                for j in 0..20 {
                    let bound = 0.5 * alphas[j] * l[(j, j)].abs() + 1e-10;
                    assert!(
                        out.resid[(i, j)].abs() <= bound,
                        "seed {seed} ({i},{j}): {} > {bound}",
                        out.resid[(i, j)].abs()
                    );
                }
            }
        }
    }

    #[test]
    fn shift_equivariance() {
        // Appendix A property 2: z_SIC(y + z·A·L) = z·A + z_SIC(y)
        let (_, _, l, y) = problem(4, 10, 7);
        let alphas = watersic_alphas(&l, 0.4);
        let out0 = zsic(&y, &l, &alphas, false, None);
        // shift row 0 by integer vector through A·L
        let mut rng = Rng::new(3);
        let zshift: Vec<f64> = (0..10).map(|_| rng.below(7) as f64 - 3.0).collect();
        let mut y2 = y.clone();
        for j in 0..10 {
            let mut acc = 0.0;
            for k in 0..10 {
                acc += zshift[k] * alphas[k] * l[(k, j)];
            }
            y2[(0, j)] += acc;
        }
        let out2 = zsic(&y2, &l, &alphas, false, None);
        for k in 0..10 {
            assert_eq!(
                out2.z[k],
                out0.z[k] + zshift[k] as i32,
                "col {k}"
            );
        }
    }

    #[test]
    fn residual_consistency() {
        // Y − Z diag(γα) L == resid
        let (_, _, l, y) = problem(9, 16, 21);
        let alphas = watersic_alphas(&l, 0.25);
        let out = zsic(&y, &l, &alphas, true, None);
        let mut zm = Mat::zeros(9, 16);
        for r in 0..9 {
            for j in 0..16 {
                zm[(r, j)] =
                    out.z[r * 16 + j] as f64 * out.gammas[j] * alphas[j];
            }
        }
        let recon = matmul(&zm, &l);
        let diff = y.sub(&recon).sub(&out.resid);
        assert!(diff.max_abs() < 1e-9);
    }

    #[test]
    fn clamp_limits_codes() {
        let (_, _, l, y) = problem(20, 12, 5);
        let alphas = gptq_alphas(12, 0.01); // tiny spacing → huge codes
        let out = zsic(&y, &l, &alphas, false, Some(3));
        assert!(out.z.iter().all(|&z| z.abs() <= 3));
    }

    #[test]
    fn lmmse_never_hurts_distortion() {
        let (w, sigma, l, y) = problem(64, 24, 77);
        let alphas = watersic_alphas(&l, 0.6);
        let plain = zsic(&y, &l, &alphas, false, None);
        let corr = zsic(&y, &l, &alphas, true, None);
        let dq = |o: &ZsicOut| {
            let mut m = Mat::zeros(64, 24);
            for r in 0..64 {
                for j in 0..24 {
                    m[(r, j)] =
                        o.z[r * 24 + j] as f64 * o.gammas[j] * alphas[j];
                }
            }
            m
        };
        let _ = y;
        let d_plain = crate::quant::distortion(&w, &dq(&plain), &sigma);
        let d_corr = crate::quant::distortion(&w, &dq(&corr), &sigma);
        // at this coarse rate LMMSE should strictly help (it optimizes
        // the per-column reconstruction); allow tiny numerical slack
        assert!(
            d_corr <= d_plain * 1.02,
            "lmmse {d_corr} vs plain {d_plain}"
        );
    }

    #[test]
    fn geomean_diag_matches_det() {
        let (_, sigma, l, _) = problem(4, 8, 2);
        let gm = geomean_diag(&l);
        let logdet = crate::linalg::chol::spd_logdet(&sigma).unwrap();
        // |Σ|^{1/2n} = exp(logdet/(2n))
        assert!((gm - (logdet / 16.0).exp()).abs() < 1e-9);
    }
}
