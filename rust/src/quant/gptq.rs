//! GPTQ and Huffman-GPTQ baselines.  The canonical GPTQ algorithm is
//! exactly ZSIC with the uniform spacing A = αI (Chen et al. 2026;
//! Birnick 2026), so it shares the ZSIC core; the `maxq` variant clamps
//! codes to a finite alphabet and reports log-cardinality rates, the
//! Huffman variant entropy-codes the unbounded codes (HPTQ).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::linalg::chol::{solve_xlt_eq_b, SpdFactor};
use crate::linalg::Mat;

use super::rescalers::effective_target;
use super::zsic::{gptq_alphas, zsic};
use super::{LayerQuant, LayerStats};

/// GPTQ at uniform spacing `alpha`; `clamp` = Some(maxq/2) reproduces
/// the finite-alphabet variant.
pub fn gptq_layer(
    w: &Mat,
    sigma: &Mat,
    alpha: f64,
    lmmse: bool,
    clamp: Option<i32>,
) -> Result<LayerQuant> {
    gptq_layer_stats(
        w,
        &LayerStats::from_sigma(sigma.clone()),
        alpha,
        lmmse,
        clamp,
        0.1,
    )
}

/// The stats-only half of the GPTQ front-end: the damped Cholesky
/// factor of Σ_X̂, which depends only on the layer statistics — never
/// on W — and can therefore be shared (via `Arc`) by every system
/// built on the same stats.  Mirror of `watersic::PreparedStats` for
/// the uniform-spacing baseline.
pub struct PreparedGptqStats {
    n: usize,
    fac: SpdFactor,
}

impl PreparedGptqStats {
    pub fn new(stats: &LayerStats, damping: f64) -> Result<PreparedGptqStats> {
        let n = stats.n();
        let mut h = stats.sigma_xhat.clone();
        let mean_diag = h.trace() / n as f64;
        h.add_diag(damping * mean_diag.max(1e-300));
        let fac = SpdFactor::new(&h).context("cholesky of damped Σ (GPTQ)")?;
        Ok(PreparedGptqStats { n, fac })
    }

    /// The damped Cholesky factor L.
    pub fn l(&self) -> &Mat {
        self.fac.l()
    }
}

/// The α-independent GPTQ front-end — shared damped Cholesky of Σ_X̂
/// ([`PreparedGptqStats`]) plus the per-W drift-corrected target solve
/// — prepared once per layer and reused across every probe of the
/// secant rate search (the uniform spacing A = αI never touches the
/// factorization).  Mirror of `watersic::PreparedLayer` for the
/// uniform-spacing baseline.
pub struct PreparedGptq {
    a: usize,
    n: usize,
    stats: Arc<PreparedGptqStats>,
    y: Mat,
}

impl PreparedGptq {
    pub fn new(w: &Mat, stats: &LayerStats, damping: f64) -> Result<PreparedGptq> {
        Self::with_stats(w, stats, Arc::new(PreparedGptqStats::new(stats, damping)?))
    }

    /// Build only the W-dependent target solve on top of an existing
    /// (shared) factorization — no factorization happens in here.
    pub fn with_stats(
        w: &Mat,
        stats: &LayerStats,
        shared: Arc<PreparedGptqStats>,
    ) -> Result<PreparedGptq> {
        let (a, n) = (w.rows, w.cols);
        anyhow::ensure!(n == shared.n, "stats dimension mismatch");
        let target = effective_target(w, stats.view());
        let y = solve_xlt_eq_b(shared.fac.l(), &target);
        Ok(PreparedGptq {
            a,
            n,
            stats: shared,
            y,
        })
    }

    /// ZSIC + rate accounting at uniform spacing `alpha` — no
    /// factorization in here.
    pub fn quantize(&self, alpha: f64, lmmse: bool, clamp: Option<i32>) -> LayerQuant {
        let (a, n) = (self.a, self.n);
        let alphas = gptq_alphas(n, alpha);
        let out = zsic(&self.y, self.stats.fac.l(), &alphas, lmmse, clamp);
        let entropy = crate::entropy::column_coded_rate(&out.z, a, n);
        let rate = match clamp {
            Some(c) => ((2 * c + 1) as f64).log2() + 16.0 / n as f64,
            None => entropy + 16.0 / a as f64 + 16.0 / n as f64,
        };
        LayerQuant {
            a,
            n,
            z: out.z,
            alphas,
            gammas: out.gammas,
            t: vec![1.0; a],
            entropy_bits: entropy,
            rate_bits: rate,
            dead_cols: vec![],
        }
    }
}

/// GPTQ with drift-aware statistics (the "quantized activation
/// statistics X̂" variant labeled Huffman-GPTQ in Appendix D) and
/// explicit damping δ (relative).
pub fn gptq_layer_stats(
    w: &Mat,
    stats: &LayerStats,
    alpha: f64,
    lmmse: bool,
    clamp: Option<i32>,
    damping: f64,
) -> Result<LayerQuant> {
    Ok(PreparedGptq::new(w, stats, damping)?.quantize(alpha, lmmse, clamp))
}

/// Huffman-GPTQ at a target entropy rate: secant on α, probing only
/// ZSIC + entropy against the once-prepared front-end.
pub fn gptq_at_rate(
    w: &Mat,
    stats: &LayerStats,
    target_bits: f64,
    lmmse: bool,
    damping: f64,
) -> Result<LayerQuant> {
    let prep = PreparedGptq::new(w, stats, damping)?;
    let sigma_w = crate::linalg::stats::variance(&w.data).sqrt();
    let rate_of = |alpha: f64| -> f64 { prep.quantize(alpha, lmmse, None).entropy_bits };
    let target_entropy = target_bits.max(0.05); // entropy-reported rates
    let a0 = (sigma_w * (2.0 * std::f64::consts::PI * std::f64::consts::E).sqrt()
        / 2f64.powf(target_entropy))
    .max(1e-9);
    let alpha = super::rate_control::secant_scale(rate_of, a0, target_entropy, 0.005, 10);
    Ok(prep.quantize(alpha, lmmse, None))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gram;
    use crate::quant::distortion;
    use crate::util::rng::Rng;

    fn problem(a: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let mut sigma =
            gram(&Mat::from_fn(2 * n, n, |_, _| rng.gaussian())).scale(1.0 / (2 * n) as f64);
        sigma.add_diag(0.05);
        (w, sigma)
    }

    #[test]
    fn gptq_beats_rtn_at_matched_entropy() {
        // the original GPTQ claim; needs *correlated* activations (real
        // LLM covariances have fast-decaying spectra — AR(1) stands in)
        let (w, _) = problem(96, 48, 1);
        let sigma = crate::quant::waterfilling::ar1_sigma(48, 0.9);
        let stats = LayerStats::from_sigma(sigma.clone());
        let q_g = gptq_at_rate(&w, &stats, 3.0, false, 0.1).unwrap();
        // match RTN to GPTQ's *achieved entropy* for a fair comparison
        let q_r = crate::quant::rtn::rtn_grid_at_rate(&w, q_g.entropy_bits);
        let d_g = distortion(&w, &q_g.dequant(), &sigma);
        let d_r = distortion(&w, &q_r.dequant(), &sigma);
        assert!(d_g < d_r, "GPTQ {d_g} must beat RTN {d_r}");
    }

    #[test]
    fn maxq_rate_is_log_cardinality() {
        let (w, sigma) = problem(16, 16, 2);
        let q = gptq_layer(&w, &sigma, 0.5, false, Some(3)).unwrap();
        assert!((q.rate_bits - ((7f64).log2() + 1.0)) < 1.1);
        assert!(q.z.iter().all(|&z| z.abs() <= 3));
    }

    #[test]
    fn rate_targeting() {
        let (w, sigma) = problem(128, 32, 3);
        let stats = LayerStats::from_sigma(sigma);
        let q = gptq_at_rate(&w, &stats, 2.5, false, 0.1).unwrap();
        assert!(
            (q.entropy_bits - 2.5).abs() < 0.06,
            "got entropy {}",
            q.entropy_bits
        );
    }

    #[test]
    fn at_rate_matches_precache_reference() {
        // pre-cache gptq_at_rate: every secant probe refactorized
        // through gptq_layer_stats — the prepared path must be
        // bit-identical
        let (w, sigma) = problem(96, 24, 4);
        let stats = LayerStats::from_sigma(sigma);
        let sigma_w = {
            let m = w.data.iter().sum::<f64>() / w.data.len() as f64;
            (w.data
                .iter()
                .map(|x| (x - m) * (x - m))
                .sum::<f64>()
                / w.data.len() as f64)
                .sqrt()
        };
        let rate_of = |alpha: f64| -> f64 {
            gptq_layer_stats(&w, &stats, alpha, false, None, 0.1)
                .map(|q| q.entropy_bits)
                .unwrap_or(f64::NAN)
        };
        let target = 2.5f64;
        let a0 = (sigma_w * (2.0 * std::f64::consts::PI * std::f64::consts::E).sqrt()
            / 2f64.powf(target))
        .max(1e-9);
        let alpha = crate::quant::rate_control::secant_scale(rate_of, a0, target, 0.005, 10);
        let q_ref = gptq_layer_stats(&w, &stats, alpha, false, None, 0.1).unwrap();
        let q = gptq_at_rate(&w, &stats, target, false, 0.1).unwrap();
        assert_eq!(q.z, q_ref.z, "codes must be bit-identical");
        assert_eq!(q.alphas, q_ref.alphas);
        assert_eq!(q.gammas, q_ref.gammas);
        assert_eq!(q.entropy_bits, q_ref.entropy_bits);
        assert_eq!(q.rate_bits, q_ref.rate_bits);
    }

    #[test]
    fn shared_stats_seam_factors_once_and_is_bit_identical() {
        // two systems on one Arc<PreparedGptqStats>: one factorization,
        // same bits as the factor-per-system constructor
        let (w, sigma) = problem(48, 16, 6);
        let stats = LayerStats::from_sigma(sigma);
        let before = crate::linalg::chol::factorization_count();
        let shared = Arc::new(PreparedGptqStats::new(&stats, 0.1).unwrap());
        let p_full = PreparedGptq::with_stats(&w, &stats, Arc::clone(&shared)).unwrap();
        let w_sub =
            w.submatrix(&(0..24).collect::<Vec<_>>(), &(0..16).collect::<Vec<_>>());
        let p_sub = PreparedGptq::with_stats(&w_sub, &stats, shared).unwrap();
        assert_eq!(
            crate::linalg::chol::factorization_count() - before,
            1,
            "one shared factorization must serve both systems"
        );
        let q = p_full.quantize(0.5, false, None);
        let q_ref = gptq_layer_stats(&w, &stats, 0.5, false, None, 0.1).unwrap();
        assert_eq!(q.z, q_ref.z);
        assert_eq!(q.alphas, q_ref.alphas);
        assert_eq!(q.gammas, q_ref.gammas);
        assert_eq!(q.entropy_bits, q_ref.entropy_bits);
        let q_sub = p_sub.quantize(0.5, false, None);
        let q_sub_ref = gptq_layer_stats(&w_sub, &stats, 0.5, false, None, 0.1).unwrap();
        assert_eq!(q_sub.z, q_sub_ref.z);
    }

    #[test]
    fn at_rate_factorizes_once() {
        let (w, sigma) = problem(64, 20, 5);
        let stats = LayerStats::from_sigma(sigma);
        let before = crate::linalg::chol::factorization_count();
        let _ = gptq_at_rate(&w, &stats, 2.0, false, 0.1).unwrap();
        assert_eq!(
            crate::linalg::chol::factorization_count() - before,
            1,
            "the secant must reuse the prepared factorization"
        );
    }
}
