//! PlainWaterSIC (Algorithm 2) and the full WaterSIC layer quantizer
//! (Algorithm 3): damping → dead-feature erasure → Cholesky →
//! drift/residual-corrected target → ZSIC with the waterfilling spacing
//! rule α_i = c/ℓ_ii and LMMSE shrinkage → rate computation → Alg. 4
//! rescaler optimization → expansion back to the full coordinate system.
//!
//! The phases split cleanly along **two** axes of dependence:
//!
//! * what depends on the spacing constant c: damping, dead-feature
//!   erasure, the Cholesky factor L, and the drift-corrected target ŷ
//!   are all c-independent, while ZSIC, the entropy, and the rescalers
//!   are per-c;
//! * what depends on the weights W: the erasure, the damped factor L of
//!   Σ_X̂, and the α-direction ℓ_ii are pure functions of the layer
//!   *statistics* — the same for the full matrix and for any row
//!   subsample of it — while W only enters through the target
//!   ŷ = (WΣ_{X,X̂}+Σ_Δ)(Lᵀ)⁻¹ and the rescaler objective.
//!
//! [`PreparedStats`] captures the stats-only front-end **once per
//! layer** and is shared via `Arc` between the full system and the row
//! subsample the secant rate search probes; [`PreparedLayer`] adds the
//! per-system W-dependent state (`w_l`, ŷ, the c₀ seed σ_W) on top.
//! The secant in [`watersic_at_rate`] therefore re-runs only
//! ZSIC + entropy coding per probe, and the whole rate-targeted layer
//! costs **one** Hessian factorization (test-pinned through
//! `linalg::chol::factorization_count`) — down from two in the
//! prepare-per-system layout and from ~11 in the factor-per-probe one.
//! The sharing itself changes no bits: at `layer_seed = 0` outputs are
//! pinned bit-identical to both earlier layouts.  (Two deliberate
//! behavior changes ride along for subsampled systems: the per-matrix
//! seed salt decorrelates same-height row draws, and the drift term is
//! sliced by the *sampled* rows instead of the first rows whenever
//! Σ_{Δ,X̂} is present.)

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::linalg::chol::{solve_xlt_eq_b, SpdFactor};
use crate::linalg::stats::{median, variance};
use crate::linalg::Mat;

use super::rescalers::{effective_target, find_optimal_rescalers};
use super::zsic::{watersic_alphas_from_diag, zsic, ZsicOut};
use super::{LayerQuant, LayerStats, QuantOpts, StatsView};

/// Pluggable ZSIC executor: the coordinator may route fixed shapes to
/// the PJRT artifact (Pallas kernel); everything else uses the native
/// implementation.  Signature matches `zsic::zsic` minus the clamp.
pub type ZsicFn<'a> = dyn Fn(&Mat, &Mat, &[f64], bool) -> ZsicOut + 'a;

/// The stats-only front-end of Algorithm 3, computed **once per layer**
/// and shared (via `Arc`) by every system built on the same activation
/// statistics — the full matrix and the row subsample of the rate
/// search: dead-feature erasure, the live-restricted covariances, the
/// damped Cholesky factor L of Σ_X̂ (held as an [`SpdFactor`] — the
/// PJRT/artifact Cholesky hook), and the α-direction ℓ_ii the spacing
/// rule divides c by.  None of it depends on W.
pub struct PreparedStats {
    n: usize,
    live: Vec<usize>,
    dead: Vec<usize>,
    /// statistics restricted to live columns; `sigma_d_xhat` is kept at
    /// the layer's full height — per-system views slice its rows
    stats_l: LayerStats,
    /// factorization of the damped Σ_X̂ (live system)
    fac: SpdFactor,
    /// ℓ_ii — the α-direction: α_i(c) = c / ℓ_ii
    chol_diag: Vec<f64>,
    /// geometric mean of √diag(Σ_X̂) on the *unreduced* system (c₀ seed)
    src_gm: f64,
}

impl PreparedStats {
    /// Run the stats-only front-end once: erasure, damping,
    /// factorization.
    pub fn new(stats: &LayerStats, opts: &QuantOpts) -> Result<PreparedStats> {
        let n = stats.n();
        let src_gm = {
            // geometric mean of damped chol diag — estimated from Σ_X̂ diag
            let d = stats.sigma_xhat.diag();
            (d.iter().map(|x| 0.5 * x.max(1e-12).ln()).sum::<f64>() / d.len() as f64).exp()
        };

        // ---- dead-feature erasure (§4): dimensions with near-zero
        // teacher variance are removed from the system and re-inserted
        // as zeros.
        let diag_x = stats.sigma_x.diag();
        let med = median(&diag_x).max(1e-300);
        let live: Vec<usize> = (0..n)
            .filter(|&j| diag_x[j] >= opts.dead_tau * med)
            .collect();
        let dead: Vec<usize> = (0..n)
            .filter(|&j| diag_x[j] < opts.dead_tau * med)
            .collect();
        let nl = live.len();
        anyhow::ensure!(nl > 0, "all features dead");

        let stats_l = LayerStats {
            sigma_x: stats.sigma_x.submatrix(&live, &live),
            sigma_xhat: stats.sigma_xhat.submatrix(&live, &live),
            sigma_x_xhat: stats.sigma_x_xhat.submatrix(&live, &live),
            sigma_d_xhat: stats
                .sigma_d_xhat
                .as_ref()
                .map(|d| d.submatrix(&(0..d.rows).collect::<Vec<_>>(), &live)),
        };

        // ---- Phase 1: damped Hessian and Cholesky
        let mut h = stats_l.sigma_xhat.clone();
        let mean_diag = h.trace() / nl as f64;
        h.add_diag(opts.damping * mean_diag.max(1e-300));
        let fac = SpdFactor::new(&h).context("cholesky of damped Σ_X̂")?;
        let chol_diag = fac.l().diag();

        Ok(PreparedStats {
            n,
            live,
            dead,
            stats_l,
            fac,
            chol_diag,
            src_gm,
        })
    }

    /// Columns zeroed by dead-feature erasure (original indices).
    pub fn dead_cols(&self) -> &[usize] {
        &self.dead
    }

    /// The damped Cholesky factor L (live system).
    pub fn l(&self) -> &Mat {
        self.fac.l()
    }
}

/// A system's view of the shared statistics: the shared live-restricted
/// covariances, with the drift term replaced by the system's own row
/// slice when one was materialized.  Single point of truth for the
/// drift fallback — the target solve and the rescaler optimization
/// must never disagree on which Σ_{Δ,X̂} rows a system sees.
fn view_of<'a>(stats: &'a PreparedStats, drift: Option<&'a Mat>) -> StatsView<'a> {
    StatsView {
        sigma_x: &stats.stats_l.sigma_x,
        sigma_xhat: &stats.stats_l.sigma_xhat,
        sigma_x_xhat: &stats.stats_l.sigma_x_xhat,
        sigma_d_xhat: drift.or(stats.stats_l.sigma_d_xhat.as_ref()),
    }
}

/// One quantizable system (the full matrix, or the row subsample the
/// secant probes) on top of a shared [`PreparedStats`]: only the
/// W-dependent state lives here — W restricted to live columns, the
/// drift-corrected target ŷ = (WΣ_{X,X̂}+Σ_Δ)(Lᵀ)⁻¹, and σ_W (the c₀
/// seed of the rate search).  `quantize` / `entropy_at` then evaluate
/// any spacing constant without touching the factorization again.
pub struct PreparedLayer {
    a: usize,
    stats: Arc<PreparedStats>,
    /// W restricted to live columns (rescaler optimization target)
    w_l: Mat,
    /// per-system drift slice — the sampled rows of the shared
    /// Σ_{Δ,X̂}, materialized only for a strict row subsample (`None`
    /// ⇒ this system is full-height and borrows the shared matrix)
    drift_l: Option<Mat>,
    /// drift-corrected target ŷ
    y: Mat,
    /// std of the source W (c₀ seed of the rate search)
    src_sigma_w: f64,
}

impl PreparedLayer {
    /// Run the whole front-end for a single system: build a private
    /// [`PreparedStats`] and the W-dependent state on top of it.
    pub fn new(w: &Mat, stats: &LayerStats, opts: &QuantOpts) -> Result<PreparedLayer> {
        Self::with_stats(w, Arc::new(PreparedStats::new(stats, opts)?))
    }

    /// Build only the W-dependent state on top of an existing (shared)
    /// [`PreparedStats`] — no factorization happens in here.
    pub fn with_stats(w: &Mat, stats: Arc<PreparedStats>) -> Result<PreparedLayer> {
        Self::with_stats_rows(w, stats, None)
    }

    /// [`with_stats`](Self::with_stats) for a system built from an
    /// explicit row subsample of the layer: `rows` are the original
    /// row indices of `w`, used to slice the shared drift term so each
    /// sampled weight row stays paired with *its own* Σ_{Δ,X̂} row.
    /// `None` falls back to rows 0..a (the full system, or a prefix
    /// slice when the caller did not say which rows it sampled).
    pub fn with_stats_rows(
        w: &Mat,
        stats: Arc<PreparedStats>,
        rows: Option<&[usize]>,
    ) -> Result<PreparedLayer> {
        let (a, n) = (w.rows, w.cols);
        anyhow::ensure!(n == stats.n, "stats dimension mismatch");

        // c₀ ingredient for the rate search, computed on the original
        // system exactly as the pre-cache search did (bit-compatible:
        // `variance` is the same two-pass population estimator)
        let src_sigma_w = variance(&w.data).sqrt();

        let w_l = w.submatrix(&(0..a).collect::<Vec<_>>(), &stats.live);
        let drift_l = match (&stats.stats_l.sigma_d_xhat, rows) {
            (Some(d), Some(r)) => {
                anyhow::ensure!(r.len() == a, "row-set length mismatch");
                Some(d.submatrix(r, &(0..d.cols).collect::<Vec<_>>()))
            }
            (Some(d), None) if a < d.rows => Some(d.submatrix(
                &(0..a).collect::<Vec<_>>(),
                &(0..d.cols).collect::<Vec<_>>(),
            )),
            _ => None,
        };

        // drift/residual-corrected target ŷ = (WΣ_{X,X̂}+Σ_Δ)(Lᵀ)⁻¹ (17)/(18)
        let target = effective_target(&w_l, view_of(&stats, drift_l.as_ref()));
        let y = solve_xlt_eq_b(stats.fac.l(), &target);

        Ok(PreparedLayer {
            a,
            stats,
            w_l,
            drift_l,
            y,
            src_sigma_w,
        })
    }

    /// The shared stats-only front-end this system is built on.
    pub fn shared_stats(&self) -> &Arc<PreparedStats> {
        &self.stats
    }

    /// Live-restricted statistics of *this* system (the drift term
    /// sliced to this system's rows).
    fn stats_view(&self) -> StatsView<'_> {
        view_of(&self.stats, self.drift_l.as_ref())
    }

    /// Columns zeroed by dead-feature erasure (original indices).
    pub fn dead_cols(&self) -> &[usize] {
        &self.stats.dead
    }

    /// Cheap secant probe: ZSIC + entropy coding only (the rescalers
    /// never change the codes, so they cannot change the entropy).
    /// Bit-identical to `quantize(c, …).entropy_bits`.
    pub fn entropy_at(&self, c: f64, opts: &QuantOpts) -> f64 {
        let nl = self.stats.live.len();
        let alphas = watersic_alphas_from_diag(&self.stats.chol_diag, c);
        let out = zsic(&self.y, self.stats.fac.l(), &alphas, opts.lmmse, None);
        let entropy = crate::entropy::column_coded_rate(&out.z, self.a, nl);
        entropy * (nl as f64 / self.stats.n as f64)
    }

    /// Phases 2–4 of Algorithm 3 at spacing constant `c`: ZSIC, rate
    /// accounting, optional rescaler optimization, and expansion back
    /// to the original coordinate system.
    pub fn quantize(&self, c: f64, opts: &QuantOpts, zsic_exec: Option<&ZsicFn>) -> LayerQuant {
        let (a, n) = (self.a, self.stats.n);
        let nl = self.stats.live.len();

        // ---- Phase 2: ZSIC with the waterfilling spacing rule
        let alphas = watersic_alphas_from_diag(&self.stats.chol_diag, c);
        let l = self.stats.fac.l();
        let out = match zsic_exec {
            Some(f) => f(&self.y, l, &alphas, opts.lmmse),
            None => zsic(&self.y, l, &alphas, opts.lmmse, None),
        };

        // ---- Phase 3: rate computation (joint entropy + side-info overhead)
        let entropy = crate::entropy::column_coded_rate(&out.z, a, nl);
        // per-weight entropy averages over the full width n (dead columns
        // cost ~0 coded bits), but the BF16 side info — one row rescaler
        // per row, one column scale per column — is stored for the full
        // matrix and must NOT shrink with dead columns
        let entropy_bits = entropy * (nl as f64 / n as f64);
        let rate_bits = entropy_bits + 16.0 / a as f64 + 16.0 / n as f64;

        // ---- Phase 4: diagonal rescaler optimization
        let mut gamma = out.gammas.clone();
        let mut t = vec![1.0; a];
        if opts.rescalers {
            let mut w0 = Mat::zeros(a, nl);
            for i in 0..a {
                for j in 0..nl {
                    w0[(i, j)] = out.z[i * nl + j] as f64 * alphas[j];
                }
            }
            let r = find_optimal_rescalers(
                &w0,
                &self.w_l,
                self.stats_view(),
                &out.gammas,
                opts.rescaler_iters,
                opts.rescaler_ridge,
                1e-7,
            );
            t = r.t;
            gamma = r.gamma;
        }

        // ---- expand the reduced system back to the original width
        let mut z_full = vec![0i32; a * n];
        let mut alphas_full = vec![1.0f64; n];
        let mut gamma_full = vec![1.0f64; n];
        for (jl, &j) in self.stats.live.iter().enumerate() {
            alphas_full[j] = alphas[jl];
            gamma_full[j] = gamma[jl];
            for i in 0..a {
                z_full[i * n + j] = out.z[i * nl + jl];
            }
        }
        // dead columns stay exactly zero (z = 0, scales neutral)
        for &j in &self.stats.dead {
            gamma_full[j] = 0.0;
        }

        LayerQuant {
            a,
            n,
            z: z_full,
            alphas: alphas_full,
            gammas: gamma_full,
            t,
            entropy_bits,
            rate_bits,
            dead_cols: self.stats.dead.clone(),
        }
    }
}

/// Quantize one layer with the full WaterSIC pipeline at spacing
/// constant `c` (rate targeting wraps this; see `watersic_at_rate`).
pub fn watersic_layer(
    w: &Mat,
    stats: &LayerStats,
    c: f64,
    opts: &QuantOpts,
    zsic_exec: Option<&ZsicFn>,
) -> Result<LayerQuant> {
    Ok(PreparedLayer::new(w, stats, opts)?.quantize(c, opts, zsic_exec))
}

/// PlainWaterSIC (Algorithm 2): no drift stats, no rescalers, no dead
/// features — exactly the object analyzed by Theorem 3.3.
pub fn plain_watersic(
    w: &Mat,
    sigma: &Mat,
    c: f64,
    lmmse: bool,
) -> Result<LayerQuant> {
    let opts = QuantOpts {
        lmmse,
        rescalers: false,
        damping: 0.0,
        dead_tau: 0.0,
        rescaler_iters: 0,
        rescaler_ridge: 0.0,
    };
    watersic_layer(w, &LayerStats::from_sigma(sigma.clone()), c, &opts, None)
}

/// A decorrelating per-matrix seed for the subsample RNG, derived from
/// the matrix name (FNV-1a).  The pipeline threads this into
/// [`prepare_at_rate`] so same-height layers — i.e. *all* the layers of
/// a model — stop drawing the same subsample rows.  0 is the legacy
/// "no per-layer salt" value (bit-compatible with the pre-fix draws).
pub fn layer_seed_from_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The row set the secant's subsample system is built on: `k` distinct
/// rows out of `a`, drawn from a seed that mixes the matrix height with
/// the per-matrix `layer_seed` salt.  `layer_seed == 0` reproduces the
/// legacy height-only seed.
pub fn subsample_row_set(a: usize, k: usize, layer_seed: u64) -> Vec<usize> {
    let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ a as u64 ^ layer_seed);
    rng.sample_indices(a, k)
}

/// Run the rate-independent front-end for [`watersic_at_rate`]: one
/// shared [`PreparedStats`] for the layer, one [`PreparedLayer`] for
/// the full matrix and, when a strict row subsample is in effect, one
/// for the subsample the secant probes — a single factorization serves
/// both systems, since L and the erasure never depend on W.  The
/// coordinator streams these over the worker pool (they are the
/// expensive, budget-independent part of a layer) and feeds them to
/// [`watersic_at_rate_prepared`] inside the sequential budget loop.
pub fn prepare_at_rate(
    w: &Mat,
    stats: &LayerStats,
    opts: &QuantOpts,
    subsample_rows: usize,
    layer_seed: u64,
) -> Result<(PreparedLayer, Option<PreparedLayer>)> {
    let a = w.rows;
    // at least 8 rows for a stable entropy estimate, capped at the
    // matrix height (max-then-min rather than `clamp(8, a)`, which
    // asserts min ≤ max and would panic on layers under 8 rows)
    let sub = subsample_rows.max(8).min(a);
    let shared = Arc::new(PreparedStats::new(stats, opts)?);
    let full = PreparedLayer::with_stats(w, Arc::clone(&shared))?;
    let subp = if sub < a {
        let rows = subsample_row_set(a, sub, layer_seed);
        let w_sub = w.submatrix(&rows, &(0..w.cols).collect::<Vec<_>>());
        Some(PreparedLayer::with_stats_rows(&w_sub, shared, Some(&rows))?)
    } else {
        None
    };
    Ok((full, subp))
}

/// Rate targeting over pre-built front-ends: the secant on c evaluates
/// only ZSIC + entropy on `prep_sub`, then the final full-matrix run
/// reuses `prep_full` — no factorization happens in here at all.
pub fn watersic_at_rate_prepared(
    prep_sub: &PreparedLayer,
    prep_full: &PreparedLayer,
    target_bits: f64,
    opts: &QuantOpts,
    zsic_exec: Option<&ZsicFn>,
) -> LayerQuant {
    // cheap evaluations on the subsample (native ZSIC — artifact shapes
    // are fixed to the full matrix)
    let rate_of = |c: f64| prep_sub.entropy_at(c, opts);
    // initial guess: for Y≈N(0,σ²) per column after whitening, entropy
    // ≈ ½log₂(2πe σ_W²/c²·|L|^{2/n}) ⇒ c ≈ σ_W·|L|^{1/n}·√(2πe)·2^{−R}
    //
    // rates are reported as entropy, matching the paper's convention for
    // entropy-coded methods ("WaterSIC and Huffman-GPTQ use entropy to
    // report rates"); the 16/a+16/n side info is tracked separately in
    // rate_bits and the container size.
    let target_entropy = target_bits.max(0.05);
    let c0 = (prep_full.src_sigma_w
        * prep_full.stats.src_gm
        * (2.0 * std::f64::consts::PI * std::f64::consts::E).sqrt()
        / 2f64.powf(target_entropy))
    .max(1e-9);
    let c = super::rate_control::secant_scale(rate_of, c0, target_entropy, 0.005, 10);
    prep_full.quantize(c, opts, zsic_exec)
}

/// Rate-targeted WaterSIC (§4 "Rate assignment"): secant on c using a
/// row subsample for the search, then one full-matrix run.  The
/// stats-only front-end (erasure + Cholesky) runs exactly once per
/// layer and is shared by both systems — see [`PreparedStats`].
pub fn watersic_at_rate(
    w: &Mat,
    stats: &LayerStats,
    target_bits: f64,
    opts: &QuantOpts,
    zsic_exec: Option<&ZsicFn>,
    subsample_rows: usize,
    layer_seed: u64,
) -> Result<LayerQuant> {
    let (full, sub) = prepare_at_rate(w, stats, opts, subsample_rows, layer_seed)?;
    Ok(watersic_at_rate_prepared(
        sub.as_ref().unwrap_or(&full),
        &full,
        target_bits,
        opts,
        zsic_exec,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gram;
    use crate::quant::{distortion, relative_distortion};
    use crate::util::rng::Rng;

    fn problem(a: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let mut sigma =
            gram(&Mat::from_fn(2 * n, n, |_, _| rng.gaussian())).scale(1.0 / (2 * n) as f64);
        sigma.add_diag(0.05);
        (w, sigma)
    }

    #[test]
    fn plain_watersic_beats_gptq_spacing() {
        // the AM/GM theorem in practice: same point density, lower D
        let (w, sigma) = problem(96, 48, 1);
        // skew the covariance so ℓ_ii spread is large
        let mut sig = sigma.clone();
        for j in 0..48 {
            let s = 0.05 + (j as f64 / 12.0).exp();
            for i in 0..48 {
                sig[(i, j)] *= s.sqrt();
                sig[(j, i)] *= s.sqrt();
            }
        }
        let l = cholesky(&sig).unwrap();
        let gm = crate::quant::zsic::geomean_diag(&l);
        let alpha = 0.25;
        let q_ws = plain_watersic(&w, &sig, alpha * gm, true).unwrap();
        let q_gptq = crate::quant::gptq::gptq_layer(&w, &sig, alpha, true, None).unwrap();
        let d_ws = distortion(&w, &q_ws.dequant(), &sig);
        let d_gq = distortion(&w, &q_gptq.dequant(), &sig);
        // equal lattice density (|A|^{1/n} = α·gm for both)
        assert!(
            d_ws < d_gq,
            "WaterSIC {d_ws:.4e} must beat GPTQ {d_gq:.4e} at equal density"
        );
    }

    #[test]
    fn rate_targeting_hits_target() {
        let (w, sigma) = problem(128, 32, 2);
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts::default();
        for target in [1.5, 2.5, 3.5] {
            let q = watersic_at_rate(&w, &stats, target, &opts, None, 64, 0).unwrap();
            assert!(
                (q.entropy_bits - target).abs() < 0.12,
                "target {target}: got entropy {}",
                q.entropy_bits
            );
        }
    }

    #[test]
    fn prepared_layer_quantize_matches_watersic_layer() {
        // the cache is pure factoring-out: same inputs, same bits
        let (w, sigma) = problem(48, 32, 9);
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts::default();
        let prep = PreparedLayer::new(&w, &stats, &opts).unwrap();
        for c in [0.2, 0.5, 1.0] {
            let q0 = watersic_layer(&w, &stats, c, &opts, None).unwrap();
            let q1 = prep.quantize(c, &opts, None);
            assert_eq!(q0.z, q1.z);
            assert_eq!(q0.alphas, q1.alphas);
            assert_eq!(q0.gammas, q1.gammas);
            assert_eq!(q0.t, q1.t);
            assert_eq!(q0.entropy_bits, q1.entropy_bits);
            assert_eq!(q0.rate_bits, q1.rate_bits);
            // the probe shortcut reports the same entropy the full
            // quantize does (rescalers never change the codes)
            assert_eq!(prep.entropy_at(c, &opts), q1.entropy_bits);
        }
    }

    #[test]
    fn shared_stats_subsample_matches_independent_prepare() {
        // the PR 3 layout factored the same statistics twice — once per
        // system; the shared PreparedStats must reproduce both systems
        // bit-for-bit (L and the erasure never depended on W)
        let (w, sigma) = problem(96, 24, 12);
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts::default();

        let (full, sub) = prepare_at_rate(&w, &stats, &opts, 32, 0).unwrap();
        let sub = sub.expect("96 rows > 32 must subsample");
        // independent per-system preparation (its own factorization)
        let full_ind = PreparedLayer::new(&w, &stats, &opts).unwrap();
        let rows = subsample_row_set(96, 32, 0);
        let w_sub = w.submatrix(&rows, &(0..w.cols).collect::<Vec<_>>());
        let sub_ind = PreparedLayer::new(&w_sub, &stats, &opts).unwrap();

        for c in [0.3, 0.9] {
            let q0 = full_ind.quantize(c, &opts, None);
            let q1 = full.quantize(c, &opts, None);
            assert_eq!(q0.z, q1.z);
            assert_eq!(q0.alphas, q1.alphas);
            assert_eq!(q0.gammas, q1.gammas);
            assert_eq!(q0.t, q1.t);
            assert_eq!(
                sub_ind.entropy_at(c, &opts),
                sub.entropy_at(c, &opts),
                "subsample probes must be bit-identical at c={c}"
            );
        }
    }

    #[test]
    fn subsample_drift_rows_follow_sampled_rows() {
        // regression: the subsample system used to slice the FIRST
        // `sub` rows of Σ_{Δ,X̂} while W_sub held randomly sampled
        // rows, pairing each sampled weight row with another row's
        // drift correction and biasing the secant's target
        let (w, sigma) = problem(96, 24, 14);
        let mut rng = Rng::new(15);
        let drift = Mat::from_fn(96, 24, |_, _| rng.gaussian());
        let stats = LayerStats {
            sigma_d_xhat: Some(drift.clone()),
            ..LayerStats::from_sigma(sigma)
        };
        let opts = QuantOpts::default();
        let (_, sub) = prepare_at_rate(&w, &stats, &opts, 32, 0).unwrap();
        let sub = sub.expect("96 rows > 32 must subsample");
        // reference: an independent prepare of the sampled system with
        // the drift term sliced by the same row set
        let rows = subsample_row_set(96, 32, 0);
        assert_ne!(
            rows,
            (0..32).collect::<Vec<_>>(),
            "draw must not be the prefix, or this test shows nothing"
        );
        let all_cols: Vec<usize> = (0..24).collect();
        let w_sub = w.submatrix(&rows, &all_cols);
        let stats_sub = LayerStats {
            sigma_d_xhat: Some(drift.submatrix(&rows, &all_cols)),
            ..LayerStats::from_sigma(stats.sigma_x.clone())
        };
        let sub_ref = PreparedLayer::new(&w_sub, &stats_sub, &opts).unwrap();
        for c in [0.3, 0.8] {
            assert_eq!(
                sub.entropy_at(c, &opts),
                sub_ref.entropy_at(c, &opts),
                "subsampled drift rows must follow the sampled row set at c={c}"
            );
        }
    }

    #[test]
    fn at_rate_matches_precache_reference() {
        // literal transcription of the pre-cache watersic_at_rate:
        // every secant probe re-runs the whole front-end (erasure +
        // Cholesky + target solve) through watersic_layer
        fn precache(
            w: &Mat,
            stats: &LayerStats,
            target_bits: f64,
            opts: &QuantOpts,
            subsample_rows: usize,
        ) -> LayerQuant {
            let a = w.rows;
            let sub = subsample_rows.clamp(8, a);
            let w_sub = if sub < a {
                let mut rng = Rng::new(0xC0FFEE ^ a as u64);
                let rows = rng.sample_indices(a, sub);
                w.submatrix(&rows, &(0..w.cols).collect::<Vec<_>>())
            } else {
                w.clone()
            };
            let rate_of = |c: f64| -> f64 {
                watersic_layer(&w_sub, stats, c, opts, None)
                    .map(|q| q.entropy_bits)
                    .unwrap_or(f64::NAN)
            };
            let sigma_w = {
                let m = w.data.iter().sum::<f64>() / w.data.len() as f64;
                (w.data
                    .iter()
                    .map(|x| (x - m) * (x - m))
                    .sum::<f64>()
                    / w.data.len() as f64)
                    .sqrt()
            };
            let gm = {
                let d = stats.sigma_xhat.diag();
                (d.iter().map(|x| 0.5 * x.max(1e-12).ln()).sum::<f64>() / d.len() as f64).exp()
            };
            let target_entropy = target_bits.max(0.05);
            let c0 = (sigma_w
                * gm
                * (2.0 * std::f64::consts::PI * std::f64::consts::E).sqrt()
                / 2f64.powf(target_entropy))
            .max(1e-9);
            let c = crate::quant::rate_control::secant_scale(
                rate_of,
                c0,
                target_entropy,
                0.005,
                10,
            );
            watersic_layer(w, stats, c, opts, None).unwrap()
        }

        let (w, sigma) = problem(128, 32, 6);
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts::default();
        for target in [1.5, 3.0] {
            let q_ref = precache(&w, &stats, target, &opts, 64);
            // layer_seed = 0 pins the legacy subsample row draw
            let q = watersic_at_rate(&w, &stats, target, &opts, None, 64, 0).unwrap();
            assert_eq!(q.z, q_ref.z, "codes must be bit-identical");
            assert_eq!(q.alphas, q_ref.alphas);
            assert_eq!(q.gammas, q_ref.gammas);
            assert_eq!(q.t, q_ref.t);
            assert_eq!(q.entropy_bits, q_ref.entropy_bits);
            assert_eq!(q.rate_bits, q_ref.rate_bits);
        }
    }

    #[test]
    fn at_rate_factorizes_once_per_layer() {
        let (w, sigma) = problem(96, 24, 8);
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts {
            rescalers: false, // the Γ-step has its own factorizations
            ..QuantOpts::default()
        };
        // subsampled search: ONE factorization serves both the
        // subsample system and the full system (the damped factor L
        // depends only on the shared statistics), no matter how many
        // secant probes run — the PR 3 layout paid two, the pre-cache
        // path one per probe
        let before = crate::linalg::chol::factorization_count();
        let _ = watersic_at_rate(&w, &stats, 2.0, &opts, None, 32, 0).unwrap();
        assert_eq!(crate::linalg::chol::factorization_count() - before, 1);
        // no subsampling: still one
        let before = crate::linalg::chol::factorization_count();
        let _ = watersic_at_rate(&w, &stats, 2.0, &opts, None, 96, 0).unwrap();
        assert_eq!(crate::linalg::chol::factorization_count() - before, 1);
    }

    #[test]
    fn equal_height_layers_draw_distinct_subsample_rows() {
        // regression: the subsample seed mixed in only the matrix
        // height, so every same-height layer of a model — i.e. all of
        // them — probed the secant on the same rows, biasing the
        // entropy estimate model-wide
        let s1 = layer_seed_from_name("layers.0.attn.wq");
        let s2 = layer_seed_from_name("layers.1.attn.wq");
        assert_ne!(s1, s2);
        assert_ne!(
            subsample_row_set(4096, 64, s1),
            subsample_row_set(4096, 64, s2),
            "same-height layers must draw different row sets"
        );
        // deterministic per (height, seed)
        assert_eq!(subsample_row_set(4096, 64, s1), subsample_row_set(4096, 64, s1));
        // layer_seed = 0 pins the legacy height-only draw
        let mut rng = Rng::new(0xC0FFEE ^ 4096);
        assert_eq!(subsample_row_set(4096, 64, 0), rng.sample_indices(4096, 64));
    }

    #[test]
    fn at_rate_handles_fewer_than_eight_rows() {
        // regression: `subsample_rows.clamp(8, a)` asserted min ≤ max
        // and panicked whenever a layer had fewer than 8 rows
        let (w, sigma) = problem(4, 12, 10);
        let stats = LayerStats::from_sigma(sigma);
        let q = watersic_at_rate(&w, &stats, 2.0, &QuantOpts::default(), None, 64, 0).unwrap();
        assert!(q.entropy_bits.is_finite());
        assert_eq!((q.a, q.n), (4, 12));
    }

    #[test]
    fn dead_features_are_erased_and_zeroed() {
        let (w, mut sigma) = problem(24, 16, 3);
        // make features 3 and 9 dead
        for &j in &[3usize, 9] {
            for i in 0..16 {
                sigma[(i, j)] = 0.0;
                sigma[(j, i)] = 0.0;
            }
            sigma[(j, j)] = 1e-12;
        }
        let stats = LayerStats::from_sigma(sigma);
        let q = watersic_layer(&w, &stats, 0.3, &QuantOpts::default(), None).unwrap();
        assert_eq!(q.dead_cols, vec![3, 9]);
        let wh = q.dequant();
        for i in 0..24 {
            assert_eq!(wh[(i, 3)], 0.0);
            assert_eq!(wh[(i, 9)], 0.0);
        }
        assert!(q.dequant().is_finite());
    }

    #[test]
    fn rate_accounting_charges_full_side_info_with_dead_columns() {
        // regression: rate_bits used to scale the whole
        // (entropy + 16/a + 16/n) sum by nl/n, under-reporting the
        // per-row/per-column side info whenever columns are dead
        let (w, mut sigma) = problem(24, 16, 7);
        for &j in &[2usize, 11] {
            for i in 0..16 {
                sigma[(i, j)] = 0.0;
                sigma[(j, i)] = 0.0;
            }
            sigma[(j, j)] = 1e-12;
        }
        let stats = LayerStats::from_sigma(sigma);
        let q = watersic_layer(&w, &stats, 0.3, &QuantOpts::default(), None)
            .unwrap();
        assert_eq!(q.dead_cols, vec![2, 11]);
        let side = 16.0 / 24.0 + 16.0 / 16.0;
        assert!(
            (q.rate_bits - (q.entropy_bits + side)).abs() < 1e-12,
            "side info must not shrink with dead columns: rate {} entropy {}",
            q.rate_bits,
            q.entropy_bits
        );
    }

    #[test]
    fn rescalers_do_not_hurt() {
        let (w, sigma) = problem(48, 32, 4);
        let stats = LayerStats::from_sigma(sigma.clone());
        let mut opts = QuantOpts {
            rescalers: false,
            ..QuantOpts::default()
        };
        let q0 = watersic_layer(&w, &stats, 0.5, &opts, None).unwrap();
        opts.rescalers = true;
        let q1 = watersic_layer(&w, &stats, 0.5, &opts, None).unwrap();
        let d0 = relative_distortion(&w, &q0.dequant(), &sigma);
        let d1 = relative_distortion(&w, &q1.dequant(), &sigma);
        assert!(d1 <= d0 * 1.01, "rescalers hurt: {d1} vs {d0}");
    }

    #[test]
    fn lower_c_means_higher_rate_lower_distortion() {
        let (w, sigma) = problem(64, 24, 5);
        let stats = LayerStats::from_sigma(sigma.clone());
        let opts = QuantOpts::default();
        let q_fine = watersic_layer(&w, &stats, 0.1, &opts, None).unwrap();
        let q_coarse = watersic_layer(&w, &stats, 0.8, &opts, None).unwrap();
        assert!(q_fine.entropy_bits > q_coarse.entropy_bits);
        let d_fine = distortion(&w, &q_fine.dequant(), &sigma);
        let d_coarse = distortion(&w, &q_coarse.dequant(), &sigma);
        assert!(d_fine < d_coarse);
    }

    use crate::linalg::chol::cholesky;
}
