//! Adaptive mixing (§4, eq. 58–60): golden-section search over the
//! drift-mixing coefficient ε_qr and the attention-weighting coefficient
//! ε_aw, each minimizing a caller-supplied objective (the w_o-input
//! relative MSE of the jointly re-quantized QKV projections).

use crate::linalg::Mat;

use super::LayerStats;

const INV_PHI: f64 = 0.618_033_988_749_894_9;

/// Golden-section minimization of a unimodal f over [lo, hi].
/// Returns (argmin, min).  `iters` function evaluations ≈ `iters`+2.
pub fn golden_section(
    mut f: impl FnMut(f64) -> f64,
    lo: f64,
    hi: f64,
    iters: usize,
) -> (f64, f64) {
    let (mut a, mut b) = (lo, hi);
    let mut c = b - INV_PHI * (b - a);
    let mut d = a + INV_PHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    for _ in 0..iters {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INV_PHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INV_PHI * (b - a);
            fd = f(d);
        }
    }
    // also probe the endpoints: the optimum is often exactly 0 or 1
    let (fl, fh) = (f(lo), f(hi));
    let mid = if fc < fd { (c, fc) } else { (d, fd) };
    let mut best = mid;
    if fl < best.1 {
        best = (lo, fl);
    }
    if fh < best.1 {
        best = (hi, fh);
    }
    best
}

/// Drift mixing (eq. 58): interpolate the drift-corrected statistics
/// toward the unquantized ones by ε_qr.
pub fn mix_drift(stats: &LayerStats, eps_qr: f64) -> LayerStats {
    let lerp = |a: &Mat, b: &Mat| a.scale(1.0 - eps_qr).add(&b.scale(eps_qr));
    LayerStats {
        sigma_x: stats.sigma_x.clone(),
        sigma_xhat: lerp(&stats.sigma_xhat, &stats.sigma_x),
        sigma_x_xhat: lerp(&stats.sigma_x_xhat, &stats.sigma_x),
        // Σ_{Δ,X̂} is a pure drift term: it vanishes as ε_qr → 1
        sigma_d_xhat: stats
            .sigma_d_xhat
            .as_ref()
            .map(|d| d.scale(1.0 - eps_qr)),
    }
}

/// Attention-weight mixing (eq. 59): interpolate the attention-weighted
/// covariances toward the uniformly-weighted (already drift-mixed) ones.
pub fn mix_attention(
    weighted: &LayerStats,
    uniform: &LayerStats,
    eps_aw: f64,
) -> LayerStats {
    let lerp = |a: &Mat, b: &Mat| a.scale(1.0 - eps_aw).add(&b.scale(eps_aw));
    LayerStats {
        sigma_x: lerp(&weighted.sigma_x, &uniform.sigma_x),
        sigma_xhat: lerp(&weighted.sigma_xhat, &uniform.sigma_xhat),
        sigma_x_xhat: lerp(&weighted.sigma_x_xhat, &uniform.sigma_x_xhat),
        sigma_d_xhat: match (&weighted.sigma_d_xhat, &uniform.sigma_d_xhat) {
            (Some(a), Some(b)) => Some(lerp(a, b)),
            (Some(a), None) => Some(a.scale(1.0 - eps_aw)),
            (None, Some(b)) => Some(b.scale(eps_aw)),
            (None, None) => None,
        },
    }
}

/// The two-stage per-layer coordinate search of Appendix C/D:
/// 1. ε_qr by golden-section with ε_aw = 0,
/// 2. ε_aw by golden-section with ε_qr fixed at its optimum.
/// `objective(eps_qr, eps_aw)` re-quantizes QKV and evaluates (60).
pub fn optimize_mixing(
    mut objective: impl FnMut(f64, f64) -> f64,
    iters: usize,
) -> (f64, f64) {
    let (eqr, _) = golden_section(|e| objective(e, 0.0), 0.0, 1.0, iters);
    let (eaw, _) = golden_section(|e| objective(eqr, e), 0.0, 1.0, iters);
    (eqr, eaw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_finds_parabola_min() {
        let (x, fx) = golden_section(|x| (x - 0.3) * (x - 0.3), 0.0, 1.0, 20);
        assert!((x - 0.3).abs() < 1e-3, "x = {x}");
        assert!(fx < 1e-6);
    }

    #[test]
    fn golden_probes_endpoints() {
        // monotone decreasing → optimum at 1.0 exactly (paper's ε_qr→1
        // "phase change" rows need this)
        let (x, _) = golden_section(|x| 1.0 - x, 0.0, 1.0, 10);
        assert_eq!(x, 1.0);
        let (x0, _) = golden_section(|x| x, 0.0, 1.0, 10);
        assert_eq!(x0, 0.0);
    }

    #[test]
    fn mix_drift_endpoints() {
        let sx = Mat::eye(3);
        let mut sxh = Mat::eye(3);
        sxh[(0, 0)] = 5.0;
        let stats = LayerStats {
            sigma_x: sx.clone(),
            sigma_xhat: sxh.clone(),
            sigma_x_xhat: sxh.clone(),
            sigma_d_xhat: Some(Mat::from_vec(2, 3, vec![1.0; 6])),
        };
        let m0 = mix_drift(&stats, 0.0);
        assert_eq!(m0.sigma_xhat, sxh); // full drift correction
        let m1 = mix_drift(&stats, 1.0);
        assert_eq!(m1.sigma_xhat, sx); // fall back to unquantized Hessian
        assert!(m1.sigma_d_xhat.unwrap().max_abs() < 1e-12);
    }

    #[test]
    fn optimize_mixing_two_stage() {
        // objective minimized at (0.7, 0.2); unimodal in each coordinate
        let (eqr, eaw) = optimize_mixing(
            |q, a| (q - 0.7) * (q - 0.7) + 0.5 * (a - 0.2) * (a - 0.2),
            12,
        );
        assert!((eqr - 0.7).abs() < 0.02, "{eqr}");
        assert!((eaw - 0.2).abs() < 0.02, "{eaw}");
    }
}
