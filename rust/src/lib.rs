//! # WaterSIC — information-theoretically (near) optimal linear layer quantization
//!
//! Full-system reproduction of Lifar, Savkin, Ordentlich & Polyanskiy
//! (ICML 2026).  Three-layer architecture:
//!
//! * **Layer 1** (build time): Pallas kernels — the ZSIC successive
//!   interference cancellation quantizer and a tiled matmul
//!   (`python/compile/kernels/`).
//! * **Layer 2** (build time): JAX compute graphs — the `picollama`
//!   transformer forward pass and the per-shape quantize graph, lowered
//!   once to HLO text (`python/compile/{model,aot}.py`).
//! * **Layer 3** (this crate): the Rust coordinator — calibration,
//!   rate control, entropy coding, the per-layer quantization pipeline,
//!   the compressed-model container, evaluation, and finetuning.  Python
//!   never runs on the request path; the binary is self-contained once
//!   `make artifacts` has been run.
//!
//! Module map mirrors DESIGN.md §3.

pub mod calib;
pub mod coordinator;
pub mod entropy;
pub mod eval;
pub mod experiments;
pub mod ft;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$WATERSIC_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/manifest.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Some(p) = util::env::string("WATERSIC_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
