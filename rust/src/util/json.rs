//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar we produce/consume: the artifact
//! manifest, model `meta.json`, and experiment result dumps.  Numbers
//! are kept as f64 (the manifest has no 64-bit integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The number as a usize, rejecting negative, fractional, and
    /// out-of-range values — an `as usize` cast would silently saturate
    /// them, turning e.g. a hostile `"steps": -3` into 0.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if !(x.fract() == 0.0 && (0.0..=usize::MAX as f64).contains(&x)) {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line serialization (the serve front door's line protocol).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, lvl: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..lvl {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting cap: far deeper than any document we produce, far shallower
/// than the stack — a hostile `[[[[…` line errors instead of
/// overflowing the recursive parser (the serve front door feeds this
/// untrusted bytes).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH}");
        }
        let v = match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        };
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            // bounds-checked: a line truncated inside
                            // the escape must error, not slice-panic
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("truncated \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let s = std::str::from_utf8(
                            self.b
                                .get(start..start + len)
                                .ok_or_else(|| anyhow!("truncated UTF-8 sequence"))?,
                        )?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

/// Convenience builders for result dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null},
                       "e": true, "f": false}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("b").unwrap().req("c").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn parses_real_manifest_style() {
        let src = r#"{"eval_batch": 8, "zsic_shapes": [[64, 64], [512, 128]],
                      "models": {"picollama_s": {"n_params": 163456}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("eval_batch").unwrap().as_usize().unwrap(), 8);
        let shapes = v.req("zsic_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[1].as_arr().unwrap()[0].as_usize().unwrap(), 512);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn truncated_escape_errors_not_panics() {
        // regression: a line ending inside a \u escape used to slice
        // b[i..i+4] out of bounds — an index panic one malformed
        // request away from killing a serve connection handler
        assert!(Json::parse(r#""\u"#).is_err());
        assert!(Json::parse(r#""\u0"#).is_err());
        assert!(Json::parse(r#"{"a": "\u00"#).is_err());
        assert!(Json::parse(r#""\uZZZZ""#).is_err());
    }

    #[test]
    fn deep_nesting_errors_not_overflows() {
        // regression: the recursive parser had no depth cap, so a
        // hostile `[[[[…` line overflowed the stack (process abort)
        let hostile = "[".repeat(100_000);
        assert!(Json::parse(&hostile).is_err());
        let deep_ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&deep_ok).is_ok());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café naïve");
    }

    #[test]
    fn compact_is_single_line_and_reparses() {
        let v = obj(vec![
            ("tokens", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("nll", Json::Num(1.25)),
        ]);
        let s = v.to_string_compact();
        assert!(!s.contains('\n'), "compact output must be one line: {s:?}");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
