//! Minimal measured-median benchmark harness (criterion is unavailable
//! offline).  Used by every target in `benches/` (declared with
//! `harness = false`).
//!
//! Protocol: warm up, then run batches until either `max_time` elapses
//! or `min_batches` are collected; report median / p10 / p90 wall time
//! per iteration and optional throughput.
//!
//! [`BenchLog`] additionally collects results into a machine-readable
//! JSON file (`BENCH_<name>.json`) so the perf trajectory is tracked
//! across PRs: each entry carries the shape name, a tag (e.g. `seed`
//! vs `packed`), percentile timings, and derived GFLOP/s.

use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

pub struct Bench {
    pub name: String,
    min_batches: usize,
    max_time: Duration,
}

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl Stats {
    pub fn per_iter_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// items/sec given an item count per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.per_iter_secs()
    }
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            min_batches: 12,
            max_time: Duration::from_secs(3),
        }
    }

    pub fn with_budget(mut self, min_batches: usize, max_time: Duration) -> Self {
        self.min_batches = min_batches;
        self.max_time = max_time;
        self
    }

    /// Run `f` repeatedly; `f` must perform exactly one "iteration".
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        // warmup + calibrate how many inner iters fill ~10ms
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let inner =
            ((Duration::from_millis(10).as_nanos() / once.as_nanos()).max(1)) as usize;

        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_batches && start.elapsed() < self.max_time
            || samples.len() < 3
        {
            let t = Instant::now();
            for _ in 0..inner {
                f();
            }
            samples.push(t.elapsed() / inner as u32);
        }
        samples.sort();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        Stats {
            name: self.name.clone(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            iters: samples.len() * inner,
        }
    }
}

/// Machine-readable benchmark sink.  Records [`Stats`] rows (plus free
/// scalar notes like speedup ratios) and serializes them with the
/// in-repo JSON writer.  The output directory defaults to the current
/// working directory and can be redirected with `WATERSIC_BENCH_DIR`.
pub struct BenchLog {
    file: String,
    entries: Vec<Json>,
    meta: Vec<(String, Json)>,
}

impl BenchLog {
    pub fn new(file: &str) -> BenchLog {
        BenchLog {
            file: file.to_string(),
            entries: Vec::new(),
            meta: vec![(
                "threads".to_string(),
                Json::Num(crate::util::threadpool::default_threads() as f64),
            )],
        }
    }

    /// Attach a top-level metadata field.
    pub fn meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Record one measured result.  `tag` distinguishes kernel
    /// generations (`seed` vs `packed`); `flops` per iteration, when
    /// known, derives a GFLOP/s field.
    pub fn record(&mut self, stats: &Stats, flops: Option<f64>, tag: &str) {
        let med = stats.median.as_secs_f64();
        let mut fields = vec![
            ("name", Json::Str(stats.name.clone())),
            ("tag", Json::Str(tag.to_string())),
            ("median_secs", Json::Num(med)),
            ("p10_secs", Json::Num(stats.p10.as_secs_f64())),
            ("p90_secs", Json::Num(stats.p90.as_secs_f64())),
            ("iters", Json::Num(stats.iters as f64)),
        ];
        if let Some(fl) = flops {
            fields.push(("flops", Json::Num(fl)));
            if med > 0.0 {
                fields.push(("gflops", Json::Num(fl / med / 1e9)));
            }
        }
        self.entries.push(obj(fields));
    }

    /// Record a derived scalar (e.g. a seed→packed speedup ratio).
    pub fn note(&mut self, name: &str, value: f64) {
        self.entries.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("tag", Json::Str("derived".to_string())),
            ("value", Json::Num(value)),
        ]));
    }

    /// Serialize to `$WATERSIC_BENCH_DIR/<file>` (cwd by default).
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir =
            crate::util::env::string("WATERSIC_BENCH_DIR").unwrap_or_else(|| ".".to_string());
        self.write_to(std::path::Path::new(&dir))
    }

    /// Serialize to an explicit directory (no env lookup — tests use
    /// this to avoid mutating process-global state).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        let path = dir.join(&self.file);
        let mut fields: Vec<(&str, Json)> = self
            .meta
            .iter()
            .map(|(k, v)| (k.as_str(), v.clone()))
            .collect();
        let entries = Json::Arr(self.entries.clone());
        fields.push(("entries", entries));
        std::fs::write(&path, obj(fields).to_string_pretty())?;
        Ok(path)
    }
}

/// Pretty-print one result row (optionally with throughput).
pub fn report(stats: &Stats, throughput: Option<(f64, &str)>) {
    let med = stats.median.as_secs_f64();
    let unit = |t: f64| {
        if t < 1e-6 {
            format!("{:8.1} ns", t * 1e9)
        } else if t < 1e-3 {
            format!("{:8.2} µs", t * 1e6)
        } else if t < 1.0 {
            format!("{:8.2} ms", t * 1e3)
        } else {
            format!("{t:8.3} s ")
        }
    };
    let tp = match throughput {
        Some((items, label)) => {
            let rate = items / med;
            if rate > 1e9 {
                format!("  {:9.2} G{label}/s", rate / 1e9)
            } else if rate > 1e6 {
                format!("  {:9.2} M{label}/s", rate / 1e6)
            } else {
                format!("  {rate:9.0} {label}/s")
            }
        }
        None => String::new(),
    };
    println!(
        "{:44} {}  [p10 {} p90 {}]{}",
        stats.name,
        unit(med),
        unit(stats.p10.as_secs_f64()),
        unit(stats.p90.as_secs_f64()),
        tp
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new("spin").with_budget(3, Duration::from_millis(200));
        let stats = b.run(|| {
            let mut x = 0u64;
            for i in 0..10_000u64 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        // in release the batched timer can round a trivial body to 0ns;
        // require only ordering + iteration accounting
        assert!(stats.p90 >= stats.median);
        assert!(stats.iters >= 3);
    }

    #[test]
    fn bench_log_serializes_and_parses_back() {
        let b = Bench::new("tiny").with_budget(3, Duration::from_millis(50));
        let s = b.run(|| {
            std::hint::black_box(1u64 + 1);
        });
        let mut log = BenchLog::new("BENCH_test_harness.json");
        log.record(&s, Some(1e6), "packed");
        log.note("speedup matmul", 2.0);
        log.meta("note", Json::Str("unit-test".into()));
        let path = log.write_to(&std::env::temp_dir()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let v = Json::parse(&text).unwrap();
        let entries = v.req("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].req("tag").unwrap().as_str().unwrap(), "packed");
        assert!(entries[0].req("gflops").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.req("threads").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn percentiles_ordered() {
        let b = Bench::new("ord").with_budget(5, Duration::from_millis(100));
        let s = b.run(|| {
            std::hint::black_box(3u32.pow(7));
        });
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }
}
