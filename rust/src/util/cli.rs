//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed getters and a usage dump.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit list (first element is NOT the program name).
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.flags
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow!("--{key} expects a number, got {s:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow!("--{key} expects an integer, got {s:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(
            self.flags.get(key).map(|s| s.as_str()),
            Some("true") | Some("1") | Some("yes")
        )
    }

    /// Comma-separated f64 list, e.g. `--rates 1.0,2.0,3.0`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow!("bad value {t:?} in --{key}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&[
            "repro", "table1", "--rate", "2.5", "--lmmse", "--model=pico",
        ]));
        assert_eq!(a.positional, vec!["repro", "table1"]);
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
        assert!(a.bool("lmmse"));
        assert_eq!(a.str_or("model", ""), "pico");
        assert!(!a.bool("absent"));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--rates", "1,2.5,4"]));
        assert_eq!(a.f64_list_or("rates", &[]).unwrap(), vec![1.0, 2.5, 4.0]);
        let b = Args::parse(&sv(&[]));
        assert_eq!(b.f64_list_or("rates", &[3.0]).unwrap(), vec![3.0]);
    }

    #[test]
    fn errors_on_bad_number() {
        let a = Args::parse(&sv(&["--rate", "abc"]));
        assert!(a.f64_or("rate", 0.0).is_err());
    }
}
