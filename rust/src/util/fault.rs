//! Deterministic fault injection for the serving stack.
//!
//! Compiled-in only under the `fault-inject` feature (zero cost
//! otherwise: [`check`] is an inlined `None`).  Faults fire at **named
//! sites** — the reactor and scheduler call `fault::check("<site>")`
//! at each injection point — according to a [`Plan`] of rules, each
//! with a deterministic [`Trigger`] (every hit, the n-th hit, every
//! k-th hit, or a seeded coin flip).  The same plan + seed always
//! yields the same fault schedule, so `rust/tests/fault.rs` can assert
//! *bit-identical* outputs for the requests a fault does not touch.
//!
//! Sites wired in this tree:
//!   `accept` — drop a connection immediately after accept
//!   `read`   — partial (1-byte) or delayed reads on a connection
//!   `conn`   — kill a connection mid-request (server-side disconnect)
//!   `write`  — stall before flushing response bytes
//!   `sched`  — panic inside a scheduler iteration (the batcher's
//!              panic isolation must contain it)
//!   `lock`   — delay (`slow:MS`/`stall:MS`) or fail (`panic`) a
//!              tracked-lock acquisition (`util::sync`), widening
//!              race windows for the fault suite
//!
//! Plans come from the `WATERSIC_FAULT` engine option (ignored in
//! non-`fault-inject` builds), or programmatically via [`install`] in
//! tests.  Spec grammar, comma-separated:
//!   `seed=N`                     seed for probabilistic triggers
//!   `<site>=<fault>[@<trigger>]` one rule
//! with `<fault>` one of `partial` | `slow:MS` | `drop` | `stall:MS` |
//! `panic`, and `<trigger>` one of `nN` (n-th hit only) | `eK` (every
//! k-th hit) | `pF` (probability F per hit); no trigger = every hit.
//! Example: `WATERSIC_FAULT="seed=7,read=partial@e2,sched=panic@n1"`.

use anyhow::{bail, Context as _, Result};

/// What happens when a rule fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// deliver at most one byte to this read pass
    PartialRead,
    /// sleep `ms` before servicing the read
    SlowRead { ms: u64 },
    /// drop the connection on the spot
    Disconnect,
    /// sleep `ms` before flushing the write
    WriteStall { ms: u64 },
    /// panic at the site
    Panic,
}

/// When a rule fires, counted per site (hit counts start at 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// every hit
    Always,
    /// only the n-th hit of the site
    Nth(u64),
    /// every k-th hit of the site
    Every(u64),
    /// seeded coin flip per hit
    Prob(f64),
}

#[derive(Clone, Debug)]
pub struct Rule {
    pub site: String,
    pub fault: Fault,
    pub trigger: Trigger,
}

/// A full fault schedule: rules plus the seed for probabilistic
/// triggers.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub seed: u64,
    pub rules: Vec<Rule>,
}

impl Plan {
    /// Parse the `WATERSIC_FAULT` spec grammar (module docs).
    pub fn parse(spec: &str) -> Result<Plan> {
        let mut plan = Plan::default();
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("fault clause {clause:?} needs '='"))?;
            if key == "seed" {
                plan.seed = val
                    .parse()
                    .with_context(|| format!("bad fault seed {val:?}"))?;
                continue;
            }
            let (fault_spec, trigger) = match val.split_once('@') {
                Some((f, t)) => (f, parse_trigger(t)?),
                None => (val, Trigger::Always),
            };
            plan.rules.push(Rule {
                site: key.to_string(),
                fault: parse_fault(fault_spec)?,
                trigger,
            });
        }
        Ok(plan)
    }
}

fn parse_fault(spec: &str) -> Result<Fault> {
    let (name, arg) = match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    };
    let ms = |arg: Option<&str>| -> Result<u64> {
        arg.with_context(|| format!("fault {name:?} needs :MS"))?
            .parse()
            .with_context(|| format!("bad ms in fault {spec:?}"))
    };
    Ok(match name {
        "partial" => Fault::PartialRead,
        "slow" => Fault::SlowRead { ms: ms(arg)? },
        "drop" => Fault::Disconnect,
        "stall" => Fault::WriteStall { ms: ms(arg)? },
        "panic" => Fault::Panic,
        other => bail!("unknown fault {other:?}"),
    })
}

fn parse_trigger(spec: &str) -> Result<Trigger> {
    let (kind, rest) = spec.split_at(spec.len().min(1));
    Ok(match kind {
        "n" => Trigger::Nth(
            rest.parse()
                .with_context(|| format!("bad trigger {spec:?}"))?,
        ),
        "e" => {
            let k: u64 = rest
                .parse()
                .with_context(|| format!("bad trigger {spec:?}"))?;
            if k == 0 {
                bail!("trigger e0 would never fire");
            }
            Trigger::Every(k)
        }
        "p" => {
            let p: f64 = rest
                .parse()
                .with_context(|| format!("bad trigger {spec:?}"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("trigger probability {p} outside [0, 1]");
            }
            Trigger::Prob(p)
        }
        _ => bail!("unknown trigger {spec:?} (want nN | eK | pF)"),
    })
}

#[cfg(feature = "fault-inject")]
mod active {
    use super::{Fault, Plan, Trigger};
    use crate::util::rng::Rng;
    use crate::util::sync::{classes, TrackedMutex};
    use std::collections::HashMap;

    struct State {
        plan: Option<Plan>,
        rng: Rng,
        hits: HashMap<String, u64>,
    }

    impl State {
        fn new(plan: Option<Plan>) -> State {
            let seed = plan.as_ref().map(|p| p.seed).unwrap_or(0);
            State {
                plan,
                rng: Rng::new(seed ^ 0x5EED_FA17),
                hits: HashMap::new(),
            }
        }

        fn from_env() -> State {
            let plan = match crate::util::env::string("WATERSIC_FAULT") {
                None => None,
                Some(spec) => match Plan::parse(&spec) {
                    Ok(p) => Some(p),
                    Err(e) => {
                        log::warn!("ignoring unparseable WATERSIC_FAULT: {e:#}");
                        None
                    }
                },
            };
            State::new(plan)
        }
    }

    // A tracked lock like everything else: the `lock` fault site's
    // re-entrancy guard (util::sync::fault_point) keeps this from
    // recursing into itself.
    static STATE: TrackedMutex<Option<State>> = TrackedMutex::new(&classes::FAULT_STATE, None);

    /// Count a hit at `site` and return the fault to inject, if any.
    pub fn check(site: &str) -> Option<Fault> {
        let mut g = STATE.lock();
        let st = g.get_or_insert_with(State::from_env);
        let State { plan, rng, hits } = st;
        let plan = plan.as_ref()?;
        let hit = hits.entry(site.to_string()).or_insert(0);
        *hit += 1;
        let count = *hit;
        for r in &plan.rules {
            if r.site != site {
                continue;
            }
            let fire = match r.trigger {
                Trigger::Always => true,
                Trigger::Nth(n) => count == n,
                Trigger::Every(k) => count % k == 0,
                Trigger::Prob(p) => (rng.below(1_000_000) as f64) < p * 1e6,
            };
            if fire {
                return Some(r.fault);
            }
        }
        None
    }

    /// Replace the global plan (fresh hit counters and RNG).
    /// `install(None)` disables injection; either way the
    /// `WATERSIC_FAULT` env spec is no longer consulted.
    pub fn install(plan: Option<Plan>) {
        let mut g = STATE.lock();
        *g = Some(State::new(plan));
    }
}

#[cfg(feature = "fault-inject")]
pub use active::{check, install};

/// No-op without the `fault-inject` feature.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn check(_site: &str) -> Option<Fault> {
    None
}

/// No-op without the `fault-inject` feature.
#[cfg(not(feature = "fault-inject"))]
pub fn install(_plan: Option<Plan>) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parser_roundtrips() {
        let p =
            Plan::parse("seed=7, read=partial@e2, write=stall:5@n3, sched=panic")
                .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0].site, "read");
        assert_eq!(p.rules[0].fault, Fault::PartialRead);
        assert_eq!(p.rules[0].trigger, Trigger::Every(2));
        assert_eq!(p.rules[1].fault, Fault::WriteStall { ms: 5 });
        assert_eq!(p.rules[1].trigger, Trigger::Nth(3));
        assert_eq!(p.rules[2].fault, Fault::Panic);
        assert_eq!(p.rules[2].trigger, Trigger::Always);
        assert!(Plan::parse("").unwrap().rules.is_empty());
        assert_eq!(
            Plan::parse("conn=drop@p0.5").unwrap().rules[0].trigger,
            Trigger::Prob(0.5)
        );
    }

    #[test]
    fn plan_parser_rejects_junk() {
        for bad in [
            "nonsense",
            "read=explode",
            "read=partial@x3",
            "read=partial@e0",
            "read=partial@p1.5",
            "read=slow",
            "write=stall:abc",
            "seed=minus-one",
        ] {
            assert!(Plan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
