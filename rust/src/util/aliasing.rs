//! Disjoint-write race checker for the unsafe kernel substrate
//! (`check-aliasing` feature; a no-op facade otherwise).
//!
//! Every raw-pointer parallel write in the tree — the packed GEMM
//! driver's C row-blocks, the syrk block-pair tiles, the Cholesky/TRSM
//! row slices, the ZSIC deferred-update rows, the transformer's
//! captured prob blocks, and `parallel_map`'s `UnsafeCell` slots —
//! relies on the same protocol: *tasks of one pool job write disjoint
//! regions*.  That protocol lives in `// SAFETY:` comments; this module
//! turns it into a runtime assertion.  Each task registers the
//! `(ptr, len[, stride])` ranges it is about to write via [`claim`] /
//! [`claim_strided`]; a per-job table asserts that no two *different*
//! tasks of the same job ever claim overlapping bytes, and panics with
//! both claims when they do (the panic propagates through the pool's
//! normal payload path, so the offending test fails cleanly).
//!
//! Scope rules:
//! - claims made outside any pool task (no enclosing `parallel_ranges`
//!   job) are ignored — serial writes cannot race;
//! - a nested job gets its own table, so an inner GEMM writing inside a
//!   region its outer task legitimately owns is not a false positive;
//! - a task's claims are checked against other tasks' claims only —
//!   re-claiming your own region (e.g. once per KC block) is fine.
//!
//! With the feature disabled every entry point is an empty `#[inline]`
//! function: release builds carry zero checker overhead.

/// Register `len` elements at `ptr` as part of the current task's
/// write-set (contiguous claim).
#[inline(always)]
pub fn claim<T>(ptr: *const T, len: usize) {
    #[cfg(feature = "check-aliasing")]
    imp::claim_bytes(ptr as usize, 1, len * std::mem::size_of::<T>(), 0);
    #[cfg(not(feature = "check-aliasing"))]
    {
        let _ = (ptr, len);
    }
}

/// Register a strided rectangle — `rows` runs of `row_len` elements,
/// successive runs `stride` elements apart — as part of the current
/// task's write-set.  This is exactly the shape of a GEMM C tile.
#[inline(always)]
pub fn claim_strided<T>(ptr: *const T, rows: usize, row_len: usize, stride: usize) {
    #[cfg(feature = "check-aliasing")]
    imp::claim_bytes(
        ptr as usize,
        rows,
        row_len * std::mem::size_of::<T>(),
        stride * std::mem::size_of::<T>(),
    );
    #[cfg(not(feature = "check-aliasing"))]
    {
        let _ = (ptr, rows, row_len, stride);
    }
}

#[cfg(feature = "check-aliasing")]
pub use imp::{job_end, next_job_id, task_scope, TaskScope};

#[cfg(feature = "check-aliasing")]
mod imp {
    use crate::util::sync::{classes, TrackedMutex};
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    thread_local! {
        /// (job id, task id) of the pool chunk running on this thread.
        static CURRENT: Cell<Option<(u64, u64)>> = const { Cell::new(None) };
    }

    /// One task's registered write rectangle, in bytes.
    struct Claim {
        task: u64,
        start: usize,
        rows: usize,
        len: usize,
        stride: usize,
    }

    impl Claim {
        fn bound_end(&self) -> usize {
            self.start + self.rows.saturating_sub(1) * self.stride + self.len
        }
    }

    struct JobClaims {
        job: u64,
        claims: Vec<Claim>,
    }

    /// Claim tables of every in-flight job (a handful at a time).
    /// Tracked: the overlap panic below fires while this lock is held,
    /// and the wrapper's poison recovery keeps that panic from
    /// cascading `PoisonError` into every *unrelated* later job (see
    /// `overlap_panic_does_not_poison_unrelated_jobs`).
    static TABLES: TrackedMutex<Vec<JobClaims>> =
        TrackedMutex::new(&classes::ALIASING_TABLES, Vec::new());

    static NEXT_JOB: AtomicU64 = AtomicU64::new(1);

    /// Fresh job identity for a `parallel_ranges` submission.
    pub fn next_job_id() -> u64 {
        NEXT_JOB.fetch_add(1, Ordering::Relaxed)
    }

    /// Marks the current thread as running task `task` of job `job`
    /// until the returned scope drops (restoring the previous task —
    /// nested submissions run inner chunks on the submitting thread).
    pub fn task_scope(job: u64, task: u64) -> TaskScope {
        let prev = CURRENT.with(|c| c.replace(Some((job, task))));
        TaskScope { prev }
    }

    pub struct TaskScope {
        prev: Option<(u64, u64)>,
    }

    impl Drop for TaskScope {
        fn drop(&mut self) {
            let prev = self.prev;
            CURRENT.with(|c| c.set(prev));
        }
    }

    /// Drop a completed job's table (called by the submitter once every
    /// chunk is accounted for).
    pub fn job_end(job: u64) {
        let mut g = TABLES.lock();
        g.retain(|t| t.job != job);
    }

    fn div_floor(a: isize, b: isize) -> isize {
        let q = a / b;
        if a % b != 0 && ((a < 0) != (b < 0)) {
            q - 1
        } else {
            q
        }
    }

    /// Exact byte-overlap test between two strided rectangles.
    fn overlaps(a: &Claim, b: &Claim) -> bool {
        if a.len == 0 || b.len == 0 || a.rows == 0 || b.rows == 0 {
            return false;
        }
        if a.bound_end() <= b.start || b.bound_end() <= a.start {
            return false;
        }
        if a.rows > 1 && b.rows > 1 && a.stride == b.stride && a.stride > 0 {
            // same stride (the common case: tiles of one matrix): row i
            // of a overlaps row j of b iff d + (i−j)·s ∈ (−b.len, a.len)
            // where d = a.start − b.start; check whether any k = i−j in
            // [−(b.rows−1), a.rows−1] lands in that open interval.
            let s = a.stride as isize;
            let d = a.start as isize - b.start as isize;
            let lo_num = -(b.len as isize) - d;
            let hi_num = a.len as isize - d;
            let k_min = -(b.rows as isize - 1);
            let k_max = a.rows as isize - 1;
            let k0 = div_floor(lo_num, s) + 1; // smallest k with k·s > lo_num
            let k = k0.max(k_min);
            return k <= k_max && k * s < hi_num;
        }
        // general case: nested row sweep (rows are ≤64 at every site)
        for i in 0..a.rows {
            let ai = a.start + i * a.stride;
            for j in 0..b.rows {
                let bj = b.start + j * b.stride;
                if ai < bj + b.len && bj < ai + a.len {
                    return true;
                }
            }
        }
        false
    }

    pub fn claim_bytes(start: usize, rows: usize, len: usize, stride: usize) {
        if len == 0 || rows == 0 {
            return;
        }
        let Some((job, task)) = CURRENT.with(|c| c.get()) else {
            return; // serial write: nothing to race with
        };
        let claim = Claim {
            task,
            start,
            rows,
            len,
            stride,
        };
        let mut g = TABLES.lock();
        let table = match g.iter_mut().find(|t| t.job == job) {
            Some(t) => t,
            None => {
                g.push(JobClaims {
                    job,
                    claims: Vec::new(),
                });
                g.last_mut().expect("just pushed")
            }
        };
        for c in &table.claims {
            if c.task != task && overlaps(c, &claim) {
                panic!(
                    "check-aliasing: overlapping parallel writes in job {job}: \
                     task {task} claims {rows}×{len}B @ {start:#x} (stride {stride}), \
                     but task {} already claimed {}×{}B @ {:#x} (stride {})",
                    c.task, c.rows, c.len, c.start, c.stride
                );
            }
        }
        table.claims.push(claim);
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn c(task: u64, start: usize, rows: usize, len: usize, stride: usize) -> Claim {
            Claim {
                task,
                start,
                rows,
                len,
                stride,
            }
        }

        #[test]
        fn contiguous_overlap_cases() {
            assert!(overlaps(&c(0, 0, 1, 40, 0), &c(1, 32, 1, 8, 0)));
            assert!(!overlaps(&c(0, 0, 1, 32, 0), &c(1, 32, 1, 8, 0)));
            assert!(overlaps(&c(0, 8, 1, 1, 0), &c(1, 0, 1, 16, 0)));
        }

        #[test]
        fn same_stride_tiles_in_one_row_band_are_disjoint() {
            // two 64×64 tiles of a 128-wide matrix, same rows,
            // adjacent column windows (the syrk block-pair layout)
            let a = c(0, 0, 64, 64, 128);
            let b = c(1, 64, 64, 64, 128);
            assert!(!overlaps(&a, &b));
            // grow one tile a single byte into the other's window
            let a_wide = c(0, 0, 64, 65, 128);
            assert!(overlaps(&a_wide, &b));
        }

        #[test]
        fn same_stride_overlapping_row_ranges_hit() {
            // row bands [0,64) and [32,96) over the same columns
            let a = c(0, 0, 64, 64, 128);
            let b = c(1, 32 * 128, 64, 64, 128);
            assert!(overlaps(&a, &b));
        }

        #[test]
        fn mixed_stride_falls_back_to_row_sweep() {
            // a contiguous row claim vs a strided tile that contains it
            let tile = c(0, 0, 4, 16, 32);
            let row = c(1, 2 * 32 + 8, 1, 4, 0);
            assert!(overlaps(&tile, &row));
            let gap_row = c(1, 2 * 32 + 16, 1, 8, 0);
            assert!(!overlaps(&tile, &gap_row));
        }
    }
}

#[cfg(all(test, feature = "check-aliasing"))]
mod tests {
    use crate::util::threadpool::{default_threads, parallel_ranges};
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Deliberately overlapping claims from two tasks must abort the
    /// job with the checker's panic (the self-test the CI feature build
    /// pins: proves detection end to end through the pool).
    #[test]
    fn injected_overlap_is_detected() {
        if default_threads() < 2 {
            return; // no pool workers: parallel_ranges degenerates to serial
        }
        let mut buf = vec![0u8; 64];
        let addr = buf.as_mut_ptr() as usize;
        let caught = std::panic::catch_unwind(|| {
            parallel_ranges(2, 2, |range| {
                for _ in range {
                    // both tasks claim the same 40-byte prefix
                    super::claim(addr as *const u8, 40);
                }
            });
        });
        let payload = caught.expect_err("overlap must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("check-aliasing: overlapping parallel writes"),
            "unexpected panic payload: {msg:?}"
        );
        buf[0] = 0; // keep the buffer alive past the job
    }

    /// Regression (poison-policy bugfix): the overlap panic fires while
    /// the global claim table is locked, which used to poison it — and
    /// every *unrelated* later job then died with `PoisonError` instead
    /// of its own result (it even made the payload assertion above
    /// scheduling-dependent: a submitter that drained both chunks hit
    /// the poisoned lock in `job_end` before it could re-raise the real
    /// panic).  The tracked wrapper's single poison policy recovers, so
    /// a clean job after a caught overlap must pass untouched.
    #[test]
    fn overlap_panic_does_not_poison_unrelated_jobs() {
        if default_threads() < 2 {
            return; // no pool workers: parallel_ranges degenerates to serial
        }
        let mut buf = vec![0u8; 1024];
        let addr = buf.as_mut_ptr() as usize;
        let caught = std::panic::catch_unwind(|| {
            parallel_ranges(2, 2, |range| {
                for _ in range {
                    super::claim(addr as *const u8, 40);
                }
            });
        });
        assert!(caught.is_err(), "overlap must panic");
        // an unrelated job with disjoint claims must still pass
        let touched = AtomicUsize::new(0);
        parallel_ranges(4, 2, |range| {
            for i in range {
                super::claim((addr + 64 + i * 8) as *const u8, 8);
                touched.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(touched.load(Ordering::SeqCst), 4);
        buf[0] = 0; // keep the buffer alive past both jobs
    }

    /// The disjoint protocol every kernel follows must sail through.
    #[test]
    fn disjoint_claims_pass() {
        let mut buf = vec![0u64; 256];
        let addr = buf.as_mut_ptr() as usize;
        let touched = AtomicUsize::new(0);
        parallel_ranges(8, 4, |range| {
            for i in range {
                super::claim((addr + i * 32 * 8) as *const u64, 32);
                touched.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(touched.load(Ordering::SeqCst), 8);
        assert_eq!(buf[0], 0);
    }

    /// Nested jobs each get their own table: an inner job writing
    /// inside its outer task's claimed region is not a conflict.
    #[test]
    fn nested_jobs_do_not_false_positive() {
        let mut buf = vec![0u64; 1024];
        let addr = buf.as_mut_ptr() as usize;
        parallel_ranges(4, 2, |outer| {
            for o in outer {
                let base = addr + o * 256 * 8;
                super::claim(base as *const u64, 256);
                parallel_ranges(4, 2, |inner| {
                    for i in inner {
                        super::claim((base + i * 64 * 8) as *const u64, 64);
                    }
                });
            }
        });
        assert_eq!(buf[0], 0);
    }
}
