//! Persistent data-parallel worker pool (tokio/rayon are unavailable
//! offline).
//!
//! The seed implementation spawned scoped `std::thread`s on *every*
//! `parallel_ranges` call — tens of microseconds of spawn/join latency
//! per gemm, paid millions of times across a pipeline run.  This
//! version keeps a lazily-initialized pool of parked workers alive for
//! the process lifetime and hands them jobs through a condvar-guarded
//! job queue; work is distributed by atomic chunk stealing, so uneven
//! ranges (triangular gram blocks, ragged tails) balance automatically.
//!
//! The public surface is unchanged: `default_threads`,
//! `parallel_ranges`, `parallel_map` — every existing call site picks
//! up the pool without churn.
//!
//! Jobs queue in a small `VecDeque` drained oldest-first: a worker
//! finishing (or waking into) the pool scans the queue for the oldest
//! job that still has unclaimed chunks and helper capacity, so nested
//! submissions (batch-parallel forwards each submitting gemm jobs) no
//! longer evict in-flight jobs to submitter-only execution — every
//! queued job keeps attracting idle workers until its chunks are
//! exhausted.  Exhausted entries are pruned on every scan and by the
//! submitter on completion, so the queue never outlives its jobs.
//!
//! Safety model: a submitted closure's lifetime is erased to `'static`
//! so parked workers can hold it.  This is sound because the submitting
//! thread (a) participates in chunk processing itself and (b) blocks
//! until every item is accounted for (`done == end`); no worker touches
//! the closure after its last `done` increment, so the borrow can never
//! outlive the submitting frame.  Panics inside chunks are caught
//! (`catch_unwind`): the first payload is stashed on the job, remaining
//! chunks are claimed-and-skipped so `done` still reaches `end`, and
//! the submitter re-raises the payload (`resume_unwind`) after every
//! in-flight worker is done touching the closure — so an assertion
//! failure inside a parallel region behaves like a normal panic to the
//! caller, and the pool stays usable.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::util::sync::{classes, TrackedCondvar, TrackedMutex};

/// Number of worker threads to use (respects `WATERSIC_THREADS`).
pub fn default_threads() -> usize {
    if let Some(n) = crate::util::env::parsed::<usize>("WATERSIC_THREADS") {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

// ---------------------------------------------------------------------
// pool internals

/// Lifetime-erased fat pointer to the job closure `(lo, hi)`.
struct TaskPtr(*const (dyn Fn(usize, usize) + Sync));
// SAFETY: the pointee is `Sync`, and the submission protocol (see
// module docs) guarantees it outlives every dereference.
unsafe impl Send for TaskPtr {}
// SAFETY: same argument as `Send` above — the pointee is `Sync`, so
// shared references may be dereferenced from any worker.
unsafe impl Sync for TaskPtr {}

struct Job {
    task: TaskPtr,
    /// check-aliasing: identity for the per-job disjoint-write table
    #[cfg(feature = "check-aliasing")]
    alias_id: u64,
    /// next unclaimed item index (claimed `chunk` at a time)
    next: AtomicUsize,
    end: usize,
    chunk: usize,
    /// items accounted for (processed or skipped-after-panic); the job
    /// is complete at `done == end`
    done: AtomicUsize,
    /// workers that joined this job (capped at `max_helpers`)
    joined: AtomicUsize,
    max_helpers: usize,
    /// set on the first chunk panic: later chunks are skipped
    panicked: std::sync::atomic::AtomicBool,
    /// payload of the first panic, re-raised by the submitter
    panic_payload: TrackedMutex<Option<Box<dyn std::any::Any + Send>>>,
    mx: TrackedMutex<()>,
    cv: TrackedCondvar,
}

struct Shared {
    /// submitted jobs, oldest first; entries are pruned once their
    /// chunks are fully claimed
    jobs: VecDeque<Arc<Job>>,
}

struct Pool {
    mx: TrackedMutex<Shared>,
    cv: TrackedCondvar,
    workers: usize,
}

static POOL: OnceLock<Arc<Pool>> = OnceLock::new();

fn pool() -> &'static Arc<Pool> {
    POOL.get_or_init(|| {
        // the submitting thread is always a participant, so park one
        // fewer worker than the target parallelism
        let workers = default_threads().saturating_sub(1);
        let pool = Arc::new(Pool {
            mx: TrackedMutex::new(
                &classes::POOL_QUEUE,
                Shared {
                    jobs: VecDeque::new(),
                },
            ),
            cv: TrackedCondvar::new(),
            workers,
        });
        for i in 0..workers {
            let p = Arc::clone(&pool);
            std::thread::Builder::new()
                .name(format!("watersic-pool-{i}"))
                .spawn(move || worker_loop(p))
                .expect("spawning pool worker");
        }
        pool
    })
}

fn worker_loop(pool: Arc<Pool>) {
    loop {
        let job = {
            let mut g = pool.mx.lock();
            loop {
                if let Some(job) = claim_job(&mut g) {
                    break job;
                }
                g = pool.cv.wait(g);
            }
        };
        run_chunks(&job);
    }
}

/// Pick the oldest queued job that still has unclaimed chunks and
/// helper capacity, registering the caller as a helper.  Exhausted
/// entries at the front are pruned.  Runs under the pool lock, so the
/// joined check/increment pair is atomic with respect to other workers.
fn claim_job(g: &mut Shared) -> Option<Arc<Job>> {
    while let Some(front) = g.jobs.front() {
        if front.next.load(Ordering::SeqCst) >= front.end {
            g.jobs.pop_front();
        } else {
            break;
        }
    }
    for job in g.jobs.iter() {
        if job.next.load(Ordering::SeqCst) < job.end
            && job.joined.load(Ordering::SeqCst) < job.max_helpers
        {
            job.joined.fetch_add(1, Ordering::SeqCst);
            return Some(Arc::clone(job));
        }
    }
    None
}

fn run_chunks(job: &Job) {
    loop {
        let lo = job.next.fetch_add(job.chunk, Ordering::SeqCst);
        if lo >= job.end {
            return;
        }
        let hi = (lo + job.chunk).min(job.end);
        if !job.panicked.load(Ordering::SeqCst) {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // check-aliasing: writes from this chunk are recorded
                // as task `lo` of this job (dropped guard restores any
                // enclosing task — nested submissions run inline here)
                #[cfg(feature = "check-aliasing")]
                let _scope = crate::util::aliasing::task_scope(job.alias_id, lo as u64);
                // SAFETY: see module docs — the submitter blocks until
                // `done == end`, and this call strictly precedes the
                // increment that can make that condition true.
                unsafe { (*job.task.0)(lo, hi) }
            }));
            if let Err(payload) = result {
                job.panicked.store(true, Ordering::SeqCst);
                let mut slot = job.panic_payload.lock();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        // count the chunk either way so the job always completes
        let prev = job.done.fetch_add(hi - lo, Ordering::SeqCst);
        if prev + (hi - lo) == job.end {
            // take the lock before notifying so the submitter cannot
            // check the predicate and sleep between our increment and
            // our notify
            let _g = job.mx.lock();
            job.cv.notify_all();
        }
    }
}

/// Split `0..n` into chunks and run `f(range)` across the persistent
/// pool, chunk-stealing for balance.  The calling thread participates,
/// so at most `threads` ranges execute concurrently.  The set of chunk
/// boundaries depends only on `(n, threads)` — never on scheduling —
/// so numeric results are reproducible run-to-run.
pub fn parallel_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0..n);
        return;
    }
    let pool = pool();
    if pool.workers == 0 {
        f(0..n);
        return;
    }

    // over-split ~4× the thread count so stragglers can be stolen, but
    // never below one item per chunk
    let chunk = n.div_ceil(threads * 4).max(1);
    let run = |lo: usize, hi: usize| f(lo..hi);
    let task_ref: &(dyn Fn(usize, usize) + Sync) = &run;
    // SAFETY: lifetime erasure; this frame does not return until
    // `done == n` (see module docs).
    let task_ref: &'static (dyn Fn(usize, usize) + Sync) =
        unsafe { std::mem::transmute(task_ref) };
    let job = Arc::new(Job {
        task: TaskPtr(task_ref as *const _),
        #[cfg(feature = "check-aliasing")]
        alias_id: crate::util::aliasing::next_job_id(),
        next: AtomicUsize::new(0),
        end: n,
        chunk,
        done: AtomicUsize::new(0),
        joined: AtomicUsize::new(0),
        max_helpers: threads - 1,
        panicked: std::sync::atomic::AtomicBool::new(false),
        panic_payload: TrackedMutex::new(&classes::POOL_PANIC, None),
        mx: TrackedMutex::new(&classes::POOL_JOB, ()),
        cv: TrackedCondvar::new(),
    });

    {
        let mut g = pool.mx.lock();
        // opportunistic prune keeps the queue bounded by in-flight jobs
        g.jobs.retain(|j| j.next.load(Ordering::SeqCst) < j.end);
        g.jobs.push_back(Arc::clone(&job));
        pool.cv.notify_all();
    }

    // participate, then wait out any stragglers
    run_chunks(&job);
    {
        let mut g = job.mx.lock();
        while job.done.load(Ordering::SeqCst) < n {
            g = job.cv.wait(g);
        }
    }
    // our job is exhausted — drop its queue entry eagerly so the deque
    // holds only live work even if no worker ever scans again
    {
        let mut g = pool.mx.lock();
        g.jobs.retain(|j| !Arc::ptr_eq(j, &job));
    }
    // the job is complete: drop its disjoint-write claim table
    #[cfg(feature = "check-aliasing")]
    crate::util::aliasing::job_end(job.alias_id);
    // every chunk is accounted for and no worker will touch the task
    // again — safe to re-raise a caught panic as our own
    let payload = job.panic_payload.lock().take();
    if let Some(p) = payload {
        std::panic::resume_unwind(p);
    }
}

/// `&[UnsafeCell<X>]` wrapper that may cross threads: every index is
/// touched by exactly one thread (disjoint ranges from
/// `parallel_ranges`), so there is no aliased access.
struct SyncSlice<'a, X>(&'a [std::cell::UnsafeCell<X>]);
// SAFETY: cells are only accessed through the disjoint index ranges
// handed out by `parallel_ranges` (see the struct docs), so no two
// threads ever touch the same slot.
unsafe impl<'a, X: Send> Sync for SyncSlice<'a, X> {}

/// Apply `f` to each item of `items`, running up to `threads` at a
/// time, preserving order of results.  Lock-free: items and result
/// slots are per-index `UnsafeCell`s claimed through the disjoint
/// ranges handed out by [`parallel_ranges`] — no global work mutex, no
/// per-slot mutexes, so layer-parallel quantization never serializes.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1);
    let n = items.len();
    if threads == 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let work: Vec<std::cell::UnsafeCell<Option<T>>> = items
        .into_iter()
        .map(|t| std::cell::UnsafeCell::new(Some(t)))
        .collect();
    let out: Vec<std::cell::UnsafeCell<Option<R>>> =
        (0..n).map(|_| std::cell::UnsafeCell::new(None)).collect();
    {
        let work_s = SyncSlice(&work);
        let out_s = SyncSlice(&out);
        parallel_ranges(n, threads, |range| {
            for i in range {
                // check-aliasing: slot i (item and result cells) is
                // this task's exclusive write-set
                crate::util::aliasing::claim(work_s.0[i].get() as *const _, 1);
                crate::util::aliasing::claim(out_s.0[i].get() as *const _, 1);
                // SAFETY: parallel_ranges hands out disjoint ranges
                // covering 0..n exactly once, so slot i has a single
                // accessor.
                let item = unsafe { (*work_s.0[i].get()).take().unwrap() };
                let r = f(item);
                // SAFETY: same disjointness argument — slot i of the
                // output has this thread as its only writer.
                unsafe {
                    *out_s.0[i].get() = Some(r);
                }
            }
        });
    }
    out.into_iter()
        .map(|c| c.into_inner().expect("parallel_map slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_single_thread_fallback() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_moves_non_copy_items() {
        let items: Vec<String> = (0..40).map(|i| format!("s{i}")).collect();
        let out = parallel_map(items, 4, |s| s.len());
        assert_eq!(out.len(), 40);
        assert_eq!(out[0], 2);
        assert_eq!(out[10], 3);
    }

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(97, 5, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn pool_survives_many_submissions() {
        // the persistent pool must be reusable back-to-back (the seed
        // spawn-per-call version trivially was; this guards the
        // queue/condvar handoff)
        for round in 0..200usize {
            let total = AtomicUsize::new(0);
            parallel_ranges(round + 1, 4, |r| {
                total.fetch_add(r.len(), Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), round + 1);
        }
    }

    #[test]
    fn nested_parallelism_completes() {
        // a job body that itself submits a job must not deadlock: the
        // inner submitter participates in its own work
        let outer_sum = AtomicUsize::new(0);
        parallel_ranges(8, 4, |outer| {
            for _ in outer {
                let inner_sum = AtomicUsize::new(0);
                parallel_ranges(50, 4, |r| {
                    inner_sum.fetch_add(r.len(), Ordering::SeqCst);
                });
                outer_sum.fetch_add(inner_sum.load(Ordering::SeqCst), Ordering::SeqCst);
            }
        });
        assert_eq!(outer_sum.load(Ordering::SeqCst), 8 * 50);
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            parallel_ranges(64, 4, |range| {
                if range.contains(&13) {
                    panic!("boom-13");
                }
            });
        });
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom-13", "original panic payload must survive");
        // the pool must remain fully usable afterwards
        let total = AtomicUsize::new(0);
        parallel_ranges(64, 4, |r| {
            total.fetch_add(r.len(), Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        // two os threads racing to submit jobs: both must finish, with
        // their jobs coexisting in the queue
        let h1 = std::thread::spawn(|| {
            let s = AtomicUsize::new(0);
            for _ in 0..50 {
                parallel_ranges(64, 4, |r| {
                    s.fetch_add(r.len(), Ordering::SeqCst);
                });
            }
            s.load(Ordering::SeqCst)
        });
        let h2 = std::thread::spawn(|| {
            let s = AtomicUsize::new(0);
            for _ in 0..50 {
                parallel_ranges(64, 4, |r| {
                    s.fetch_add(r.len(), Ordering::SeqCst);
                });
            }
            s.load(Ordering::SeqCst)
        });
        assert_eq!(h1.join().unwrap(), 50 * 64);
        assert_eq!(h2.join().unwrap(), 50 * 64);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn queued_jobs_get_worker_participation() {
        // Regression for the single-job-slot starvation: a job
        // submitted while every worker is pinned elsewhere, then
        // shadowed by a *newer* submission, must still attract workers
        // once they free up (the old slot dropped it forever and its
        // submitter drained it alone).
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        use std::time::Duration;

        let t = default_threads();
        if t < 3 {
            // needs ≥2 pool workers for participation to be observable
            return;
        }

        // X: pin the submitter and every worker on a spin gate (t
        // chunks of size 1, one per participant)
        let x_gate = Arc::new(AtomicBool::new(false));
        let x_claimed = Arc::new(AtomicUsize::new(0));
        let (xg, xc) = (Arc::clone(&x_gate), Arc::clone(&x_claimed));
        let s_x = std::thread::spawn(move || {
            parallel_ranges(t, t, |r| {
                for _ in r {
                    xc.fetch_add(1, Ordering::SeqCst);
                    while !xg.load(Ordering::SeqCst) {
                        std::thread::yield_now();
                    }
                }
            });
        });
        while x_claimed.load(Ordering::SeqCst) < t {
            std::thread::yield_now();
        }

        // A: submitted while no worker is free; its chunks take long
        // enough that released workers can join mid-flight
        let non_submitter_hits = Arc::new(AtomicUsize::new(0));
        let nsh = Arc::clone(&non_submitter_hits);
        let (tx, rx) = std::sync::mpsc::channel();
        let s_a = std::thread::spawn(move || {
            let me = std::thread::current().id();
            tx.send(()).unwrap();
            parallel_ranges(16, 4, |r| {
                for _ in r {
                    if std::thread::current().id() != me {
                        nsh.fetch_add(1, Ordering::SeqCst);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            });
        });
        rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(20));

        // B: a newer job — with the single slot this evicted A
        parallel_ranges(4, 2, |_r| {});

        // release the pinned workers; they must find A in the queue
        x_gate.store(true, Ordering::SeqCst);
        s_x.join().unwrap();
        s_a.join().unwrap();
        assert!(
            non_submitter_hits.load(Ordering::SeqCst) > 0,
            "no pool worker ever joined the queued job"
        );
    }
}
