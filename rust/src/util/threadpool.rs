//! Scoped data-parallel helpers built on `std::thread` (tokio/rayon are
//! unavailable offline).  The coordinator uses `parallel_map` to quantize
//! the independent matrices of a layer concurrently, and `parallel_chunks`
//! for row-parallel gemm in the hot path.

/// Number of worker threads to use (respects `WATERSIC_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("WATERSIC_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Apply `f` to each item of `items`, running up to `threads` at a time,
/// preserving order of results.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let work: std::sync::Mutex<Vec<Option<T>>> =
        std::sync::Mutex::new(items.into_iter().map(Some).collect());
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        results.iter_mut().map(std::sync::Mutex::new).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let item = work.lock().unwrap()[i].take().unwrap();
                let r = f(item);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(slots);
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Split `0..n` into contiguous ranges and run `f(range)` on each in
/// parallel.  Used for row-blocked gemm.
pub fn parallel_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(lo..hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_single_thread_fallback() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn ranges_cover_everything_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(97, 5, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
