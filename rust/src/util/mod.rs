//! Infrastructure substrates the offline environment cannot pull from
//! crates.io: RNG, JSON, npy IO, a CLI parser, a scoped thread pool, and
//! a criterion-style bench harness.

pub mod aliasing;
pub mod bench;
pub mod cli;
pub mod env;
pub mod fault;
pub mod json;
pub mod npy;
pub mod rng;
pub mod sync;
pub mod threadpool;

/// Round half-to-even for f64 — matches `numpy.round` / `jnp.round` and
/// the Pallas kernel, bit-for-bit on .5 ties.  The single rounding rule
/// used by every quantizer in the crate.
#[inline]
pub fn round_ties_even(x: f64) -> f64 {
    x.round_ties_even()
}

/// log2 that maps 0 → 0 (for entropy sums).
#[inline]
pub fn xlog2x(p: f64) -> f64 {
    if p <= 0.0 {
        0.0
    } else {
        p * p.log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_even_matches_numpy() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(0.4999), 0.0);
        assert_eq!(round_ties_even(2.501), 3.0);
    }

    #[test]
    fn xlog2x_zero() {
        assert_eq!(xlog2x(0.0), 0.0);
        assert!((xlog2x(0.5) + 0.5).abs() < 1e-12);
    }
}
