//! Deterministic RNG substrate (crates.io `rand` is unavailable offline).
//!
//! Xoshiro256++ for uniforms, Box–Muller for Gaussians (cached spare),
//! plus the helpers the experiments need: Gaussian matrices, AR(1) and
//! spiked covariance sampling, permutations.

/// Xoshiro256++ PRNG (Blackman & Vigna).  Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (spare cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn gaussian_vec(&mut self, n: usize, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| sigma * self.gaussian()).collect()
    }

    /// Standard Laplace (for the Fig. 11 weight-fit experiments).
    pub fn laplace(&mut self) -> f64 {
        let u = self.uniform() - 0.5;
        -u.signum() * (1.0 - 2.0 * u.abs()).ln() * std::f64::consts::FRAC_1_SQRT_2
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices out of `n` (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_variance_is_one() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let var = (0..n)
            .map(|_| {
                let x = r.laplace();
                x * x
            })
            .sum::<f64>()
            / n as f64;
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
