//! Registry of every `WATERSIC_*` engine option.
//!
//! This module is the **single** place in the tree that reads a
//! `WATERSIC_*` environment variable (`xtask lint` rule `env-registry`
//! enforces it): a knob that is not listed in [`KNOBS`] cannot be read,
//! and a knob that is listed must be documented in the `main.rs` USAGE
//! text (a unit test there pins the other direction).  Before this
//! registry existed, 11 knobs were scattered raw `std::env::var` calls
//! across eight modules and only six were documented.
//!
//! The typed accessors mirror the historical per-site semantics
//! exactly: an *unset* variable and an *unparseable* value both fall
//! back to the caller's default, so rewiring a call site through the
//! registry can never change behavior.

/// One registered engine option.
pub struct Knob {
    /// Environment variable name (`WATERSIC_*`).
    pub name: &'static str,
    /// Human-readable default, for the USAGE text.
    pub default: &'static str,
    /// One-line description, for the USAGE text.
    pub doc: &'static str,
}

/// Every engine option the tree reads, in USAGE display order.
pub static KNOBS: &[Knob] = &[
    Knob {
        name: "WATERSIC_PRECISION",
        default: "f64",
        doc: "kernel/pack precision: f64 | f32",
    },
    Knob {
        name: "WATERSIC_THREADS",
        default: "auto (≤16)",
        doc: "worker-pool width (outputs bit-identical across N)",
    },
    Knob {
        name: "WATERSIC_SIMD",
        default: "auto",
        doc: "force the scalar kernel rung with `scalar` (others auto-detect)",
    },
    Knob {
        name: "WATERSIC_LOG",
        default: "unset",
        doc: "set (any value) to enable debug-level logging",
    },
    Knob {
        name: "WATERSIC_ARTIFACTS",
        default: "auto",
        doc: "AOT artifacts dir (default: walk up for artifacts/manifest.json)",
    },
    Knob {
        name: "WATERSIC_PREPARE_LOOKAHEAD",
        default: "2",
        doc: "prepared-layer front-ends alive at once in the streaming prepare",
    },
    Knob {
        name: "WATERSIC_SERVE_BATCH",
        default: "8",
        doc: "max prefill rows / active generations per scheduler step",
    },
    Knob {
        name: "WATERSIC_SERVE_FLUSH_US",
        default: "500",
        doc: "partial-batch flush deadline in microseconds",
    },
    Knob {
        name: "WATERSIC_SERVE_KV_BUDGET",
        default: "1 GiB",
        doc: "KV-cache byte budget across in-flight sequences",
    },
    Knob {
        name: "WATERSIC_SERVE_MAX_STEPS",
        default: "256",
        doc: "per-request generation-step cap",
    },
    Knob {
        name: "WATERSIC_SERVE_QUEUE",
        default: "64",
        doc: "bounded admission-queue depth; beyond it requests shed with `overloaded`",
    },
    Knob {
        name: "WATERSIC_SERVE_DEADLINE_MS",
        default: "0 (off)",
        doc: "default per-request deadline; expired work is cancelled at step granularity",
    },
    Knob {
        name: "WATERSIC_SERVE_MAX_CONNS",
        default: "1024",
        doc: "hard cap on concurrent front-door connections",
    },
    Knob {
        name: "WATERSIC_SERVE_IDLE_MS",
        default: "60000",
        doc: "per-connection idle timeout (no request bytes, nothing in flight)",
    },
    Knob {
        name: "WATERSIC_SERVE_WRITE_MS",
        default: "10000",
        doc: "per-connection write-stall timeout on unflushed response bytes",
    },
    Knob {
        name: "WATERSIC_SERVE_WEIGHTS",
        default: "dequant",
        doc: "serving weight residency: dequant (eager panels) | coded (quantized codes)",
    },
    Knob {
        name: "WATERSIC_FAULT",
        default: "unset",
        doc: "fault-injection plan (fault-inject builds only; see util::fault)",
    },
    Knob {
        name: "WATERSIC_BENCH_DIR",
        default: ".",
        doc: "directory BENCH_*.json telemetry is written to",
    },
    Knob {
        name: "WATERSIC_BENCH_ENFORCE",
        default: "0",
        doc: "set to 1 to turn bench speedup targets into hard gates",
    },
    Knob {
        name: "WATERSIC_SERVE_CLIENTS",
        default: "8",
        doc: "bench_serve: concurrent load-test clients",
    },
    Knob {
        name: "WATERSIC_SERVE_REQUESTS",
        default: "8",
        doc: "bench_serve: requests per load-test client",
    },
];

fn registered(name: &str) -> bool {
    KNOBS.iter().any(|k| k.name == name)
}

/// Raw read of a registered knob.  Panics in debug builds if `name` is
/// not in [`KNOBS`] — reads of unregistered knobs are a programmer
/// error (and `xtask lint` flags the literal too).
pub fn string(name: &'static str) -> Option<String> {
    debug_assert!(registered(name), "unregistered engine option {name}");
    std::env::var(name).ok()
}

/// `true` iff the knob is set at all (regardless of value).
pub fn is_set(name: &'static str) -> bool {
    string(name).is_some()
}

/// `true` iff the knob is set to exactly `"1"`.
pub fn flag(name: &'static str) -> bool {
    string(name).as_deref() == Some("1")
}

/// Parse a registered knob; `None` when unset **or** unparseable (every
/// historical call site treated those two the same way).
pub fn parsed<T: std::str::FromStr>(name: &'static str) -> Option<T> {
    string(name).and_then(|v| v.parse::<T>().ok())
}

/// Parse with a default (unset/unparseable → `default`).
pub fn usize_or(name: &'static str, default: usize) -> usize {
    parsed(name).unwrap_or(default)
}

/// The `ENGINE OPTIONS (env)` block of the USAGE text, generated from
/// the registry so documentation cannot drift from the code.
pub fn usage_block() -> String {
    let mut out = String::from("ENGINE OPTIONS (env):\n");
    for k in KNOBS {
        let head = format!("  {}", k.name);
        out.push_str(&format!("{head:<31} {} (default {})\n", k.doc, k.default));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_knob_is_watersic_prefixed_and_unique() {
        for (i, k) in KNOBS.iter().enumerate() {
            assert!(k.name.starts_with("WATERSIC_"), "{}", k.name);
            assert!(!k.doc.is_empty() && !k.default.is_empty(), "{}", k.name);
            for other in &KNOBS[i + 1..] {
                assert_ne!(k.name, other.name, "duplicate knob");
            }
        }
    }

    #[test]
    fn usage_block_mentions_every_knob() {
        let block = usage_block();
        for k in KNOBS {
            assert!(block.contains(k.name), "missing {}", k.name);
        }
    }

    #[test]
    fn accessors_fall_back_on_unset() {
        assert_eq!(string("WATERSIC_LOG").is_some(), is_set("WATERSIC_LOG"));
        assert!(usize_or("WATERSIC_SERVE_BATCH", 8) >= 1);
    }
}
