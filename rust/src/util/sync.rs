//! Tracked lock primitives: the only sanctioned home of `std::sync`
//! locks in this tree (the `watersic-lint` rule `no-raw-sync` bans
//! them everywhere else).
//!
//! In release builds [`TrackedMutex`] / [`TrackedRwLock`] /
//! [`TrackedCondvar`] are zero-cost transparent wrappers: `lock()`
//! inlines to the std acquisition plus the poison policy below, and
//! the per-lock [`LockClass`] pointer is the only extra state.
//!
//! Under `--features check-locks` every acquisition is checked against
//! a lockdep-style rank discipline:
//!
//! - each lock registers a [`LockClass`] with a numeric rank (the
//!   repo-wide table lives in [`classes`]); nesting must go strictly
//!   *upward* in rank,
//! - a per-thread stack of held locks catches inversions at the
//!   acquisition that would close a cycle, panicking with **both**
//!   acquisition sites,
//! - every observed (outer, inner) nesting is recorded into a
//!   process-global acquisition-order graph ([`order_edges`]), so one
//!   checked run documents the discipline actually exercised,
//! - a condvar wait may hold only its own guard plus strictly
//!   lower-rank (outer) locks: a same-or-higher-rank lock held across
//!   a wait would deadlock the waker that needs it, and panics
//!   *before* blocking.
//!
//! # Poison policy
//!
//! All wrappers recover from poisoning via
//! `unwrap_or_else(PoisonError::into_inner)` — the one documented
//! policy for the whole tree.  Every guarded region here either keeps
//! its invariants at each intermediate panic point (counters, queues,
//! claim tables), or the poisoning panic *is* the primary failure
//! being reported (a checker firing, an injected fault).  Cascading
//! `PoisonError` panics into unrelated threads buries that primary
//! failure — the pre-tracked claim table did exactly that (see
//! `overlap_panic_does_not_poison_unrelated_jobs` in
//! `util/aliasing.rs`).
//!
//! # Fault injection
//!
//! With `--features fault-inject`, acquisitions pass through the
//! `lock` fault site before touching the lock: `slow:MS` / `stall:MS`
//! delay the acquisition (widening race windows for the fault suite)
//! and `panic` fails it.  See `util/fault.rs` for the plan grammar.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock};
use std::sync::{RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult};
use std::time::Duration;

/// A named lock class with a total-order rank.  Within one thread,
/// locks must be acquired in strictly increasing rank order.
pub struct LockClass {
    name: &'static str,
    rank: u32,
}

impl LockClass {
    pub const fn new(name: &'static str, rank: u32) -> LockClass {
        LockClass { name, rank }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }
}

/// The repo-wide rank table.  Outer (coarse) locks rank low, leaf
/// locks rank high; acquisition must go low → high.  Gaps are left so
/// new classes slot in without renumbering.
pub mod classes {
    use super::LockClass;

    /// Test-binary environment serialization (`env_lock()` in the
    /// integration suites).  Rank 0: held around whole test bodies,
    /// outside every runtime lock.
    pub static TEST_ENV: LockClass = LockClass::new("test.env", 0);
    /// Server request queue + scheduler state (`runtime/server.rs`).
    pub static SERVE_QUEUE: LockClass = LockClass::new("serve.queue", 10);
    /// Bounded prepare-window state (`coordinator/pipeline.rs`).
    pub static PIPELINE_WINDOW: LockClass = LockClass::new("pipeline.window", 20);
    /// PJRT executable cache (`runtime/engine.rs`).
    pub static ENGINE_CACHE: LockClass = LockClass::new("engine.cache", 30);
    /// Open-loop load-test collector handoff (`runtime/server.rs`).
    pub static SERVE_LOADTEST: LockClass = LockClass::new("serve.loadtest", 40);
    /// Thread-pool shared job queue (`util/threadpool.rs`).
    pub static POOL_QUEUE: LockClass = LockClass::new("pool.queue", 50);
    /// Per-job completion latch (`util/threadpool.rs`).
    pub static POOL_JOB: LockClass = LockClass::new("pool.job", 60);
    /// Per-job panic-payload slot (`util/threadpool.rs`).
    pub static POOL_PANIC: LockClass = LockClass::new("pool.panic", 65);
    /// Installed fault plan (`util/fault.rs`).  Near-leaf: the `lock`
    /// fault site consults it from inside other acquisitions.
    pub static FAULT_STATE: LockClass = LockClass::new("fault.state", 80);
    /// check-aliasing claim tables (`util/aliasing.rs`).  Leaf:
    /// claims happen under arbitrary job locks.
    pub static ALIASING_TABLES: LockClass = LockClass::new("aliasing.tables", 90);
}

/// A mutex registered under a [`LockClass`].
pub struct TrackedMutex<T: ?Sized> {
    class: &'static LockClass,
    inner: Mutex<T>,
}

impl<T> TrackedMutex<T> {
    /// `const`, so tracked locks can live in `static` items (the
    /// installed fault plan, the test-env locks).
    pub const fn new(class: &'static LockClass, value: T) -> TrackedMutex<T> {
        TrackedMutex {
            class,
            inner: Mutex::new(value),
        }
    }
}

impl<T: ?Sized> TrackedMutex<T> {
    pub fn class(&self) -> &'static LockClass {
        self.class
    }

    /// Acquire.  Recovers from poisoning (module docs), passes the
    /// `lock` fault site, and under `check-locks` enforces the rank
    /// discipline *before* blocking on the inner lock.
    #[inline]
    #[track_caller]
    pub fn lock(&self) -> TrackedMutexGuard<'_, T> {
        fault_point();
        #[cfg(feature = "check-locks")]
        let held = check::acquired(self.class);
        TrackedMutexGuard {
            #[cfg(feature = "check-locks")]
            held,
            guard: self.inner.lock().unwrap_or_else(PoisonError::into_inner),
        }
    }
}

/// RAII guard for [`TrackedMutex`].  Under `check-locks` it also owns
/// the held-stack entry, which unregisters itself on drop (guards may
/// drop in any order, not just LIFO).
pub struct TrackedMutexGuard<'a, T: ?Sized> {
    #[cfg(feature = "check-locks")]
    held: check::Held,
    guard: MutexGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for TrackedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T: ?Sized> std::ops::DerefMut for TrackedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

/// A condvar that pairs with [`TrackedMutex`] guards.
pub struct TrackedCondvar {
    inner: Condvar,
}

impl TrackedCondvar {
    pub const fn new() -> TrackedCondvar {
        TrackedCondvar {
            inner: Condvar::new(),
        }
    }

    /// Wait, re-acquiring the same tracked lock on wake.  Under
    /// `check-locks`, panics *before* blocking if any held lock other
    /// than the guard's own has rank >= the guard's class: the waker
    /// that should wake us may need that inner lock.
    #[track_caller]
    pub fn wait<'a, T: ?Sized>(&self, guard: TrackedMutexGuard<'a, T>) -> TrackedMutexGuard<'a, T> {
        #[cfg(feature = "check-locks")]
        check::waiting(&guard.held);
        #[cfg(feature = "check-locks")]
        let held = guard.held;
        let inner = self
            .inner
            .wait(guard.guard)
            .unwrap_or_else(PoisonError::into_inner);
        TrackedMutexGuard {
            #[cfg(feature = "check-locks")]
            held,
            guard: inner,
        }
    }

    /// [`Self::wait`] with a timeout; identical checking.
    #[track_caller]
    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        guard: TrackedMutexGuard<'a, T>,
        dur: Duration,
    ) -> (TrackedMutexGuard<'a, T>, WaitTimeoutResult) {
        #[cfg(feature = "check-locks")]
        check::waiting(&guard.held);
        #[cfg(feature = "check-locks")]
        let held = guard.held;
        let (inner, timeout) = self
            .inner
            .wait_timeout(guard.guard, dur)
            .unwrap_or_else(PoisonError::into_inner);
        (
            TrackedMutexGuard {
                #[cfg(feature = "check-locks")]
                held,
                guard: inner,
            },
            timeout,
        )
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

/// An rwlock registered under a [`LockClass`].  Both `read()` and
/// `write()` follow the same strict rank order — in particular a
/// re-entrant `read()` of one class panics under `check-locks`,
/// because a writer queued between the two reads deadlocks both.
pub struct TrackedRwLock<T: ?Sized> {
    class: &'static LockClass,
    inner: RwLock<T>,
}

impl<T> TrackedRwLock<T> {
    pub const fn new(class: &'static LockClass, value: T) -> TrackedRwLock<T> {
        TrackedRwLock {
            class,
            inner: RwLock::new(value),
        }
    }
}

impl<T: ?Sized> TrackedRwLock<T> {
    pub fn class(&self) -> &'static LockClass {
        self.class
    }

    #[inline]
    #[track_caller]
    pub fn read(&self) -> TrackedReadGuard<'_, T> {
        fault_point();
        #[cfg(feature = "check-locks")]
        let held = check::acquired(self.class);
        TrackedReadGuard {
            #[cfg(feature = "check-locks")]
            _held: held,
            guard: self.inner.read().unwrap_or_else(PoisonError::into_inner),
        }
    }

    #[inline]
    #[track_caller]
    pub fn write(&self) -> TrackedWriteGuard<'_, T> {
        fault_point();
        #[cfg(feature = "check-locks")]
        let held = check::acquired(self.class);
        TrackedWriteGuard {
            #[cfg(feature = "check-locks")]
            _held: held,
            guard: self.inner.write().unwrap_or_else(PoisonError::into_inner),
        }
    }
}

/// Shared-access RAII guard for [`TrackedRwLock`].
pub struct TrackedReadGuard<'a, T: ?Sized> {
    #[cfg(feature = "check-locks")]
    _held: check::Held,
    guard: RwLockReadGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for TrackedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

/// Exclusive-access RAII guard for [`TrackedRwLock`].
pub struct TrackedWriteGuard<'a, T: ?Sized> {
    #[cfg(feature = "check-locks")]
    _held: check::Held,
    guard: RwLockWriteGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for TrackedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T: ?Sized> std::ops::DerefMut for TrackedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

/// The `lock` fault site (`--features fault-inject`): delays or fails
/// an acquisition *before* the lock is touched.  The installed plan
/// itself lives behind a `TrackedMutex`, so a thread-local
/// re-entrancy flag keeps the hook from recursing into itself.
#[cfg(feature = "fault-inject")]
#[inline]
fn fault_point() {
    use std::cell::Cell;
    thread_local! {
        static IN_HOOK: Cell<bool> = const { Cell::new(false) };
    }
    let entered = IN_HOOK.with(|flag| {
        if flag.get() {
            false
        } else {
            flag.set(true);
            true
        }
    });
    if !entered {
        return;
    }
    let fault = crate::util::fault::check("lock");
    IN_HOOK.with(|flag| flag.set(false));
    match fault {
        Some(crate::util::fault::Fault::SlowRead { ms })
        | Some(crate::util::fault::Fault::WriteStall { ms }) => {
            std::thread::sleep(Duration::from_millis(ms));
        }
        Some(crate::util::fault::Fault::Panic) => {
            panic!("injected fault: lock acquisition");
        }
        _ => {}
    }
}

#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
fn fault_point() {}

#[cfg(feature = "check-locks")]
mod check {
    use super::LockClass;
    use std::cell::RefCell;
    use std::panic::Location;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, PoisonError};

    struct HeldEntry {
        class: &'static LockClass,
        site: &'static Location<'static>,
        token: u64,
    }

    thread_local! {
        static HELD: RefCell<Vec<HeldEntry>> = const { RefCell::new(Vec::new()) };
    }

    /// Tokens make guard drops order-independent: entries are removed
    /// by identity, not by popping, so guards may drop out of
    /// acquisition order.
    static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);

    struct Edge {
        outer: &'static LockClass,
        inner: &'static LockClass,
        outer_site: &'static Location<'static>,
        inner_site: &'static Location<'static>,
    }

    /// Process-global acquisition-order graph.  A raw `Mutex` over a
    /// const-initializable `Vec` (edge counts are tiny): the
    /// checker's own state cannot go through the tracked wrappers it
    /// implements.
    static EDGES: Mutex<Vec<Edge>> = Mutex::new(Vec::new());

    /// Held-stack entry owned by a guard; unregisters itself on drop.
    pub(super) struct Held {
        token: u64,
    }

    impl Drop for Held {
        fn drop(&mut self) {
            let token = self.token;
            HELD.with(|held| {
                let mut held = held.borrow_mut();
                if let Some(at) = held.iter().position(|e| e.token == token) {
                    held.remove(at);
                }
            });
        }
    }

    #[track_caller]
    pub(super) fn acquired(class: &'static LockClass) -> Held {
        let site = Location::caller();
        let token = NEXT_TOKEN.fetch_add(1, Ordering::Relaxed);
        HELD.with(|held| {
            let mut held = held.borrow_mut();
            if let Some(blocker) = held.iter().find(|e| e.class.rank >= class.rank) {
                panic!(
                    "check-locks: lock-order inversion: acquiring {} (rank {}) at {} \
                     while holding {} (rank {}) acquired at {}",
                    class.name, class.rank, site, blocker.class.name, blocker.class.rank, blocker.site,
                );
            }
            let mut edges = EDGES.lock().unwrap_or_else(PoisonError::into_inner);
            for outer in held.iter() {
                let dup = edges
                    .iter()
                    .any(|e| std::ptr::eq(e.outer, outer.class) && std::ptr::eq(e.inner, class));
                if !dup {
                    edges.push(Edge {
                        outer: outer.class,
                        inner: class,
                        outer_site: outer.site,
                        inner_site: site,
                    });
                }
            }
            drop(edges);
            held.push(HeldEntry { class, site, token });
        });
        Held { token }
    }

    /// The pre-block condvar check: with `own` about to be released
    /// for the wait, every *other* held lock must rank strictly below
    /// `own`'s class (a true outer lock).  Runs before blocking, so a
    /// violation panics instead of deadlocking.
    #[track_caller]
    pub(super) fn waiting(own: &Held) {
        let wait_site = Location::caller();
        HELD.with(|held| {
            let held = held.borrow();
            let own_entry = held
                .iter()
                .find(|e| e.token == own.token)
                .expect("check-locks: condvar guard missing from the held stack");
            for other in held.iter() {
                if other.token != own.token && other.class.rank >= own_entry.class.rank {
                    panic!(
                        "check-locks: condvar wait at {} would release {} (rank {}) \
                         while holding {} (rank {}) acquired at {} — an inner lock \
                         held across a wait deadlocks its waker",
                        wait_site,
                        own_entry.class.name,
                        own_entry.class.rank,
                        other.class.name,
                        other.class.rank,
                        other.site,
                    );
                }
            }
        });
    }

    /// Snapshot of the global order graph:
    /// `(outer class, inner class, outer site, inner site)` rows.
    pub fn order_edges() -> Vec<(String, String, String, String)> {
        let edges = EDGES.lock().unwrap_or_else(PoisonError::into_inner);
        edges
            .iter()
            .map(|e| {
                (
                    e.outer.name.to_string(),
                    e.inner.name.to_string(),
                    e.outer_site.to_string(),
                    e.inner_site.to_string(),
                )
            })
            .collect()
    }
}

#[cfg(feature = "check-locks")]
pub use check::order_edges;

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn mutex_roundtrip_with_in_rank_nesting() {
        let outer = TrackedMutex::new(&classes::SERVE_QUEUE, 1u32);
        let inner = TrackedMutex::new(&classes::POOL_QUEUE, 2u32);
        assert_eq!(outer.class().name(), "serve.queue");
        {
            let g1 = outer.lock();
            let mut g2 = inner.lock();
            *g2 += *g1;
        }
        assert_eq!(*inner.lock(), 3);
    }

    #[test]
    fn rwlock_roundtrip() {
        let l = TrackedRwLock::new(&classes::ENGINE_CACHE, 0u32);
        {
            let mut w = l.write();
            *w = 7;
        }
        assert_eq!(*l.read(), 7);
        assert_eq!(l.class().rank(), 30);
    }

    #[test]
    fn condvar_wakes_waiter() {
        let flag = TrackedMutex::new(&classes::PIPELINE_WINDOW, false);
        let cv = TrackedCondvar::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut g = flag.lock();
                *g = true;
                cv.notify_all();
            });
            let mut g = flag.lock();
            while !*g {
                g = cv.wait(g);
            }
        });
    }

    #[test]
    fn condvar_wait_timeout_returns_guard() {
        let flag = TrackedMutex::new(&classes::PIPELINE_WINDOW, 41u32);
        let cv = TrackedCondvar::new();
        let g = flag.lock();
        // no notifier: spurious wakes are allowed, but the guard must
        // come back owning the same lock
        let (mut g, _timed_out) = cv.wait_timeout(g, Duration::from_millis(1));
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn poisoned_lock_recovers_with_inner_value() {
        let m = TrackedMutex::new(&classes::ENGINE_CACHE, 5u32);
        let err = catch_unwind(AssertUnwindSafe(|| {
            let mut g = m.lock();
            *g = 6;
            panic!("poison it");
        }));
        assert!(err.is_err());
        // the single poison policy: recover and keep serving
        assert_eq!(*m.lock(), 6);
    }
}

#[cfg(all(test, feature = "check-locks"))]
mod check_tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn panic_message(err: Box<dyn std::any::Any + Send>) -> String {
        match err.downcast::<String>() {
            Ok(s) => *s,
            Err(err) => match err.downcast::<&'static str>() {
                Ok(s) => s.to_string(),
                Err(_) => String::from("<non-string panic payload>"),
            },
        }
    }

    #[test]
    fn rank_inversion_panics_with_both_sites() {
        let low = TrackedMutex::new(&classes::SERVE_QUEUE, ());
        let high = TrackedMutex::new(&classes::ALIASING_TABLES, ());
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _gh = high.lock();
            let _gl = low.lock(); // inversion: rank 90 held, acquiring rank 10
        }))
        .expect_err("inverted acquisition must panic");
        let msg = panic_message(err);
        assert!(msg.contains("lock-order inversion"), "{msg}");
        assert!(msg.contains("serve.queue"), "{msg}");
        assert!(msg.contains("aliasing.tables"), "{msg}");
        // both acquisition sites must be named, and both are in this file
        assert!(msg.matches("sync.rs").count() >= 2, "{msg}");
    }

    #[test]
    fn same_class_reentry_panics() {
        let a = TrackedMutex::new(&classes::POOL_JOB, ());
        let _g = a.lock();
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _again = a.lock();
        }))
        .expect_err("same-rank re-entry must panic");
        assert!(panic_message(err).contains("lock-order inversion"));
    }

    #[test]
    fn condvar_wait_with_inner_lock_held_panics_before_blocking() {
        let outer = TrackedMutex::new(&classes::SERVE_QUEUE, ());
        let inner = TrackedMutex::new(&classes::POOL_QUEUE, ());
        let cv = TrackedCondvar::new();
        // no notifier exists: if the check ran after blocking instead
        // of before, this test would hang, not fail
        let err = catch_unwind(AssertUnwindSafe(|| {
            let g_outer = outer.lock();
            let _g_inner = inner.lock();
            let _ = cv.wait(g_outer);
        }))
        .expect_err("wait holding an inner lock must panic, not block");
        let msg = panic_message(err);
        assert!(msg.contains("condvar wait"), "{msg}");
        assert!(msg.contains("pool.queue"), "{msg}");
    }

    #[test]
    fn wait_holding_only_outer_locks_is_allowed() {
        // the serve-suite pattern: a rank-0 env lock held around a
        // body that internally waits on higher-rank locks
        let outer = TrackedMutex::new(&classes::TEST_ENV, ());
        let flag = TrackedMutex::new(&classes::POOL_JOB, false);
        let cv = TrackedCondvar::new();
        let _outer_guard = outer.lock();
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut g = flag.lock();
                *g = true;
                cv.notify_all();
            });
            let mut g = flag.lock();
            while !*g {
                g = cv.wait(g);
            }
        });
    }

    #[test]
    fn order_graph_records_nesting_edges() {
        let outer = TrackedMutex::new(&classes::PIPELINE_WINDOW, ());
        let inner = TrackedMutex::new(&classes::FAULT_STATE, ());
        let _go = outer.lock();
        let _gi = inner.lock();
        let edges = order_edges();
        assert!(
            edges
                .iter()
                .any(|(o, i, _, _)| o == "pipeline.window" && i == "fault.state"),
            "{edges:?}"
        );
    }
}
