//! Reader/writer for the NumPy `.npy` format (v1.0), C-contiguous,
//! little-endian `f32`/`i32` — the weight interchange format between
//! the build-time python trainer and the Rust runtime.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8] = b"\x93NUMPY";

#[derive(Clone, Debug, PartialEq)]
pub struct Npy {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum NpyData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Npy {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Npy {
            shape,
            data: NpyData::F32(data),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            NpyData::F32(v) => Ok(v),
            _ => bail!("npy is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            NpyData::I32(v) => Ok(v),
            _ => bail!("npy is not i32"),
        }
    }

    pub fn read(path: &Path) -> Result<Npy> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&raw).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(raw: &[u8]) -> Result<Npy> {
        if raw.len() < 10 || &raw[..6] != MAGIC {
            bail!("bad npy magic");
        }
        let (major, _minor) = (raw[6], raw[7]);
        let (hlen, hstart) = if major == 1 {
            (u16::from_le_bytes([raw[8], raw[9]]) as usize, 10)
        } else {
            (
                u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize,
                12,
            )
        };
        let header = std::str::from_utf8(&raw[hstart..hstart + hlen])?;
        let descr_rest = extract(header, "'descr':")?;
        let descr_field = descr_rest.split(',').next().unwrap_or("");
        let fortran = extract(header, "'fortran_order':")?;
        if fortran.trim_start().starts_with("True") {
            bail!("fortran order unsupported");
        }
        let shape_str = extract(header, "'shape':")?;
        let shape = parse_shape(shape_str)?;
        let n: usize = shape.iter().product();
        let body = &raw[hstart + hlen..];
        let descr = descr_field.trim().trim_matches(|c| c == '\'' || c == '"');
        let data = match descr {
            "<f4" | "|f4" => {
                if body.len() < 4 * n {
                    bail!("truncated f32 body");
                }
                let mut v = Vec::with_capacity(n);
                for i in 0..n {
                    v.push(f32::from_le_bytes(
                        body[4 * i..4 * i + 4].try_into().unwrap(),
                    ));
                }
                NpyData::F32(v)
            }
            "<i4" | "|i4" => {
                if body.len() < 4 * n {
                    bail!("truncated i32 body");
                }
                let mut v = Vec::with_capacity(n);
                for i in 0..n {
                    v.push(i32::from_le_bytes(
                        body[4 * i..4 * i + 4].try_into().unwrap(),
                    ));
                }
                NpyData::I32(v)
            }
            other => bail!("unsupported dtype {other:?} (want <f4 or <i4)"),
        };
        Ok(Npy { shape, data })
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let descr = match self.data {
            NpyData::F32(_) => "<f4",
            NpyData::I32(_) => "<i4",
        };
        let shape = match self.shape.len() {
            1 => format!("({},)", self.shape[0]),
            _ => format!(
                "({})",
                self.shape
                    .iter()
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}"
        );
        // pad so that data starts at a multiple of 64
        let unpadded = MAGIC.len() + 4 + header.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(1);
        out.push(0);
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        match &self.data {
            NpyData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            NpyData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }
}

fn extract<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let pos = header
        .find(key)
        .with_context(|| format!("npy header missing {key}"))?;
    Ok(&header[pos + key.len()..])
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    let open = s.find('(').context("no ( in shape")?;
    let close = s[open..].find(')').context("no ) in shape")? + open;
    let inner = &s[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        shape.push(t.parse::<usize>()?);
    }
    if shape.is_empty() {
        shape.push(1); // 0-d scalar treated as shape (1,)
    }
    Ok(shape)
}

/// Read every `.npy` file in a directory into (stem → array).
pub fn read_dir(dir: &Path) -> Result<std::collections::BTreeMap<String, Npy>> {
    let mut out = std::collections::BTreeMap::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
    {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("npy") {
            let stem = path
                .file_stem()
                .unwrap()
                .to_string_lossy()
                .into_owned();
            out.insert(stem, Npy::read(&path)?);
        }
    }
    Ok(out)
}

/// Read a whole file into bytes (tiny helper used by corpus loading).
pub fn read_bytes(path: &Path) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let a = Npy::f32(vec![3, 4], (0..12).map(|i| i as f32 * 0.5).collect());
        let b = Npy::parse(&a.to_bytes()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn roundtrip_i32_1d() {
        let a = Npy {
            shape: vec![5],
            data: NpyData::I32(vec![-2, -1, 0, 1, 2]),
        };
        let b = Npy::parse(&a.to_bytes()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn parses_python_written_header_variants() {
        // header with different spacing, as numpy itself writes it
        let a = Npy::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut bytes = a.to_bytes();
        // mutate header spacing minimally: parse should be robust anyway
        let b = Npy::parse(&bytes).unwrap();
        assert_eq!(b.shape, vec![2, 2]);
        // corrupt magic
        bytes[0] = 0;
        assert!(Npy::parse(&bytes).is_err());
    }
}
