//! Micro-batching inference server over a prepacked `.wsic` model —
//! the serving path of the reproduction (continuous-batching designs à
//! la Orca/vLLM, scaled to this repo's CPU substrate).
//!
//! Concurrent scoring/generation requests land in a queue; a batcher
//! thread coalesces them — up to `WATERSIC_SERVE_BATCH` requests per
//! forward, with a deadline-based flush (`WATERSIC_SERVE_FLUSH_US`) so
//! a lone request never waits for a full batch — pads them to a
//! uniform window length, runs **one** batched [`forward_packed`] over
//! the persistent worker pool, and fans the responses back out.
//!
//! Why padding is sound: attention is causal within each window, RoPE
//! positions are window-relative, and the prepacked GEMM entries fix
//! every output row's reduction order independently of the batch row
//! count (see [`crate::linalg::gemm::PrepackedB`]).  A request's
//! response is therefore **bit-identical** no matter which micro-batch
//! it rides in, how many co-batched requests surround it, or how many
//! worker threads run the kernels — the serve parity tests pin this.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context as _, Result};

use crate::coordinator::container::Container;
use crate::linalg::gemm::Precision;
use crate::linalg::Mat;
use crate::model::transformer::{forward_packed, ForwardOpts};
use crate::model::weights::{PackedWeights, Weights};
use crate::model::ModelConfig;
use crate::util::json::{obj, Json};

/// The `WATERSIC_SERVE_BATCH` engine option: max requests coalesced
/// into one batched forward.  Default 8, minimum 1 (no batching).
pub fn serve_batch_from_env() -> usize {
    std::env::var("WATERSIC_SERVE_BATCH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(8)
}

/// The `WATERSIC_SERVE_FLUSH_US` engine option: how long (µs) the
/// batcher holds a partial batch open for co-arriving requests before
/// flushing it.  Default 500µs; 0 flushes immediately.
pub fn serve_flush_us_from_env() -> u64 {
    std::env::var("WATERSIC_SERVE_FLUSH_US")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(500)
}

#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// max requests per batched forward
    pub batch_max: usize,
    /// deadline a partial batch is held open for
    pub flush: Duration,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            batch_max: serve_batch_from_env(),
            flush: Duration::from_micros(serve_flush_us_from_env()),
        }
    }
}

/// Response to one scoring request.
#[derive(Clone, Debug)]
pub struct ScoreOut {
    /// logits at the last real token of the window (vocab-sized) —
    /// enough for greedy/sampled continuation and parity checks
    pub logits_last: Vec<f64>,
    /// mean next-token NLL over the window, nats (0.0 when len < 2)
    pub nll: f64,
    /// real (unpadded) window length
    pub len: usize,
    /// how many requests rode in the same micro-batch (telemetry)
    pub batched_with: usize,
}

impl ScoreOut {
    /// Greedy next token (ties keep the last index, matching
    /// [`crate::model::transformer::greedy_continuation`]).
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.logits_last.iter().enumerate() {
            if v >= self.logits_last[best] {
                best = i;
            }
        }
        best
    }
}

struct Pending {
    tokens: Vec<i32>,
    resp: mpsc::Sender<ScoreOut>,
}

struct Queue {
    q: VecDeque<Pending>,
    shutdown: bool,
}

/// Cumulative server counters (monotone; snapshot-diff around a run to
/// measure it in isolation).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    /// real (unpadded) tokens forwarded
    pub tokens: usize,
    pub max_batch: usize,
}

struct Inner {
    cfg: ModelConfig,
    model: PackedWeights,
    opts: ServeOpts,
    queue: Mutex<Queue>,
    cv: Condvar,
    requests: AtomicUsize,
    batches: AtomicUsize,
    tokens: AtomicUsize,
    max_batch: AtomicUsize,
}

/// In-flight request handle; [`ScoreHandle::wait`] blocks for the
/// batched response.
pub struct ScoreHandle {
    rx: mpsc::Receiver<ScoreOut>,
}

impl ScoreHandle {
    pub fn wait(self) -> Result<ScoreOut> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("serve request dropped before completion"))
    }
}

/// The serving engine: owns the prepacked model and the batcher
/// thread.  Cheap to share behind an `Arc` (all methods take `&self`);
/// dropping it drains the queue and joins the batcher.
pub struct Server {
    inner: Arc<Inner>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving a prepacked model.
    pub fn start(cfg: ModelConfig, model: PackedWeights, opts: ServeOpts) -> Server {
        let inner = Arc::new(Inner {
            cfg,
            model,
            opts,
            queue: Mutex::new(Queue {
                q: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            tokens: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
        });
        let worker = inner.clone();
        let batcher = std::thread::Builder::new()
            .name("watersic-serve-batcher".to_string())
            .spawn(move || batcher_loop(&worker))
            .expect("spawning serve batcher");
        Server {
            inner,
            batcher: Some(batcher),
        }
    }

    /// Load path: dequantize a `.wsic` container over the base weights,
    /// prepack at the given precision, start serving.
    pub fn from_container(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
        opts: ServeOpts,
    ) -> Result<Server> {
        let packed = PackedWeights::from_container(cfg, base, container, prec)?;
        Ok(Server::start(cfg.clone(), packed, opts))
    }

    /// Enqueue a scoring request (returns immediately).
    pub fn submit(&self, tokens: Vec<i32>) -> Result<ScoreHandle> {
        ensure!(!tokens.is_empty(), "empty token window");
        ensure!(
            tokens.len() <= self.inner.cfg.ctx,
            "window of {} exceeds ctx {}",
            tokens.len(),
            self.inner.cfg.ctx
        );
        for &t in &tokens {
            ensure!(
                t >= 0 && (t as usize) < self.inner.cfg.vocab,
                "token {t} outside vocab {}",
                self.inner.cfg.vocab
            );
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut g = self.inner.queue.lock().unwrap();
            if g.shutdown {
                bail!("server is shutting down");
            }
            g.q.push_back(Pending { tokens, resp: tx });
        }
        self.inner.requests.fetch_add(1, Ordering::Relaxed);
        self.inner.cv.notify_all();
        Ok(ScoreHandle { rx })
    }

    /// Score a window, blocking for the batched response.
    pub fn score(&self, tokens: Vec<i32>) -> Result<ScoreOut> {
        self.submit(tokens)?.wait()
    }

    /// Greedy continuation driven through the batched score path —
    /// each step rides whatever micro-batch is in flight alongside
    /// other clients' requests.
    pub fn generate(&self, prompt: &[i32], steps: usize) -> Result<Vec<i32>> {
        ensure!(!prompt.is_empty(), "empty prompt");
        let mut toks = prompt.to_vec();
        for _ in 0..steps {
            let start = toks.len() - toks.len().min(self.inner.cfg.ctx);
            let out = self.score(toks[start..].to_vec())?;
            toks.push(out.argmax() as i32);
        }
        Ok(toks)
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.inner.requests.load(Ordering::Relaxed),
            batches: self.inner.batches.load(Ordering::Relaxed),
            tokens: self.inner.tokens.load(Ordering::Relaxed),
            max_batch: self.inner.max_batch.load(Ordering::Relaxed),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.inner.cfg
    }

    /// Bytes held by the prepacked panels (load-time telemetry).
    pub fn packed_bytes(&self) -> usize {
        self.inner.model.packed_bytes()
    }

    /// Drain the queue, stop the batcher, and return the final
    /// counters.  Also runs on drop (without the counters).
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        {
            let mut g = self.inner.queue.lock().unwrap();
            g.shutdown = true;
        }
        self.inner.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn batcher_loop(inner: &Inner) {
    loop {
        let batch: Vec<Pending> = {
            let mut g = inner.queue.lock().unwrap();
            loop {
                if !g.q.is_empty() {
                    break;
                }
                if g.shutdown {
                    return;
                }
                g = inner.cv.wait(g).unwrap();
            }
            // deadline-based coalescing: hold the partial batch open a
            // short window for co-arriving requests
            let deadline = Instant::now() + inner.opts.flush;
            while g.q.len() < inner.opts.batch_max && !g.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (ng, _) = inner.cv.wait_timeout(g, deadline - now).unwrap();
                g = ng;
            }
            let take = g.q.len().min(inner.opts.batch_max);
            g.q.drain(..take).collect()
        };
        // a panicking forward must not kill the batcher: the moved-in
        // senders drop on unwind, so the affected clients see an error
        // while later requests keep being served
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_batch(inner, batch)
        }));
        if res.is_err() {
            log::warn!("serve batch panicked; affected requests dropped");
        }
    }
}

fn run_batch(inner: &Inner, batch: Vec<Pending>) {
    let b = batch.len();
    if b == 0 {
        return;
    }
    let t_max = batch.iter().map(|p| p.tokens.len()).max().unwrap();
    // pad each window to the batch max with token 0: causal attention
    // and window-relative RoPE keep every row before the pad
    // bit-identical to the unpadded forward (module docs)
    let mut toks = Vec::with_capacity(b * t_max);
    let mut real_tokens = 0;
    for p in &batch {
        real_tokens += p.tokens.len();
        toks.extend_from_slice(&p.tokens);
        toks.resize(toks.len() + (t_max - p.tokens.len()), 0);
    }
    let out = forward_packed(
        &inner.cfg,
        &inner.model,
        &toks,
        b,
        t_max,
        &ForwardOpts::default(),
    );
    inner.batches.fetch_add(1, Ordering::Relaxed);
    inner.tokens.fetch_add(real_tokens, Ordering::Relaxed);
    inner.max_batch.fetch_max(b, Ordering::Relaxed);
    for (i, p) in batch.into_iter().enumerate() {
        let base = i * t_max;
        let len = p.tokens.len();
        let score = ScoreOut {
            logits_last: out.logits.row(base + len - 1).to_vec(),
            nll: window_nll(&out.logits, base, &p.tokens),
            len,
            batched_with: b,
        };
        // a client that gave up (dropped its handle) is not an error
        let _ = p.resp.send(score);
    }
}

/// Mean next-token NLL (nats) of one window whose rows start at `base`
/// in the batched logits; 0.0 for single-token windows.
fn window_nll(logits: &Mat, base: usize, tokens: &[i32]) -> f64 {
    if tokens.len() < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    for pos in 0..tokens.len() - 1 {
        let row = logits.row(base + pos);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        total += lse - row[tokens[pos + 1] as usize];
    }
    total / (tokens.len() - 1) as f64
}

// ---------------------------------------------------------------------
// self-driving load test (the CI serve-smoke driver)

/// Result of one [`load_test`] run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub clients: usize,
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_secs: f64,
    /// real tokens scored per second across all clients
    pub throughput_tok_s: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub batches: usize,
    pub mean_batch: f64,
    pub max_batch: usize,
}

impl LoadReport {
    pub fn print(&self) {
        println!(
            "load test: {} clients x {} requests  ({} tokens, {:.2}s wall)",
            self.clients,
            self.requests / self.clients.max(1),
            self.total_tokens,
            self.wall_secs
        );
        println!("  throughput : {:.0} tok/s", self.throughput_tok_s);
        println!(
            "  latency    : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
            self.p50_ms, self.p90_ms, self.p99_ms
        );
        println!(
            "  batching   : {} batches (mean {:.2}, max {})",
            self.batches, self.mean_batch, self.max_batch
        );
    }
}

/// Drive the server with `clients` concurrent threads, each submitting
/// `per_client` scoring requests over deterministic token windows of
/// varying length, and measure per-request wall latency plus end-to-end
/// token throughput.
pub fn load_test(
    server: &Server,
    clients: usize,
    per_client: usize,
    seed: u64,
) -> Result<LoadReport> {
    ensure!(clients >= 1 && per_client >= 1, "empty load test");
    let cfg = server.config();
    let (vocab, ctx) = (cfg.vocab, cfg.ctx);
    let before = server.stats();
    let t0 = Instant::now();
    let lat_tok: Vec<(f64, usize, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<Vec<(f64, usize, usize)>> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut out = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let len = 4 + rng.below(ctx.saturating_sub(3).max(1));
                        let len = len.min(ctx);
                        let tokens: Vec<i32> =
                            (0..len).map(|_| rng.below(vocab) as i32).collect();
                        let t = Instant::now();
                        let score = server.score(tokens)?;
                        out.push((
                            t.elapsed().as_secs_f64() * 1e3,
                            score.len,
                            score.batched_with,
                        ));
                    }
                    Ok(out)
                })
            })
            .collect();
        let mut all = Vec::new();
        let mut err = None;
        for h in handles {
            match h.join().expect("load-test client panicked") {
                Ok(v) => all.extend(v),
                Err(e) => err = Some(e),
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(all),
        }
    })?;
    let wall_secs = t0.elapsed().as_secs_f64();
    let after = server.stats();
    let total_tokens: usize = lat_tok.iter().map(|&(_, n, _)| n).sum();
    // run-local, like batches/requests: derived from this run's own
    // responses, not the server-lifetime high-water mark
    let max_batch = lat_tok.iter().map(|&(_, _, b)| b).max().unwrap_or(0);
    let mut lats: Vec<f64> = lat_tok.iter().map(|&(l, _, _)| l).collect();
    lats.sort_by(f64::total_cmp);
    let pick = |q: f64| lats[((lats.len() - 1) as f64 * q) as usize];
    let batches = after.batches - before.batches;
    Ok(LoadReport {
        clients,
        requests: lats.len(),
        total_tokens,
        wall_secs,
        throughput_tok_s: total_tokens as f64 / wall_secs.max(1e-9),
        p50_ms: pick(0.5),
        p90_ms: pick(0.9),
        p99_ms: pick(0.99),
        batches,
        mean_batch: lats.len() as f64 / batches.max(1) as f64,
        max_batch,
    })
}

// ---------------------------------------------------------------------
// line-JSON front door (the TCP protocol body, kept here so the lib
// tests cover it; main.rs only wires the sockets)

/// Handle one line of the serve protocol and serialize the response.
/// Requests:
///   `{"tokens": [..]}`               → `{"len", "next", "nll", "batched_with"}`
///   `{"prompt": [..], "steps": N}`   → `{"tokens": [..]}`
/// Errors come back as `{"error": "..."}` lines — a malformed request
/// never kills the connection.
pub fn handle_request_line(server: &Server, line: &str) -> String {
    match handle_request_inner(server, line) {
        Ok(j) => j.to_string_compact(),
        Err(e) => obj(vec![("error", Json::Str(format!("{e:#}")))]).to_string_compact(),
    }
}

fn parse_tokens(j: &Json) -> Result<Vec<i32>> {
    j.as_arr()?
        .iter()
        .map(|v| {
            let x = v.as_f64()?;
            ensure!(
                x.fract() == 0.0 && (0.0..2_147_483_648.0).contains(&x),
                "bad token {x}"
            );
            Ok(x as i32)
        })
        .collect()
}

fn handle_request_inner(server: &Server, line: &str) -> Result<Json> {
    let req = Json::parse(line).context("parsing request")?;
    if let Some(toks) = req.get("tokens") {
        let out = server.score(parse_tokens(toks)?)?;
        return Ok(obj(vec![
            ("len", Json::Num(out.len as f64)),
            ("next", Json::Num(out.argmax() as f64)),
            ("nll", Json::Num(out.nll)),
            ("batched_with", Json::Num(out.batched_with as f64)),
        ]));
    }
    if let Some(prompt) = req.get("prompt") {
        let steps = match req.get("steps") {
            Some(s) => s.as_usize()?,
            None => 8,
        };
        ensure!(steps <= 256, "steps capped at 256");
        let toks = server.generate(&parse_tokens(prompt)?, steps)?;
        return Ok(obj(vec![(
            "tokens",
            Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect()),
        )]));
    }
    bail!("request needs \"tokens\" or \"prompt\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_server(batch_max: usize, flush: Duration) -> Server {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 21);
        let pw = PackedWeights::new(&cfg, w, Precision::F64);
        Server::start(
            cfg,
            pw,
            ServeOpts {
                batch_max,
                flush,
            },
        )
    }

    #[test]
    fn score_returns_vocab_logits_and_counts() {
        let server = tiny_server(4, Duration::from_micros(200));
        let out = server.score(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(out.logits_last.len(), 128);
        assert_eq!(out.len, 4);
        assert!(out.batched_with >= 1);
        assert!(out.nll.is_finite());
        assert!(out.argmax() < 128);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.tokens, 4);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn submit_validates_windows() {
        let server = tiny_server(2, Duration::from_micros(0));
        assert!(server.submit(vec![]).is_err());
        assert!(server.submit(vec![0; 13]).is_err()); // ctx = 12
        assert!(server.submit(vec![-1]).is_err());
        assert!(server.submit(vec![128]).is_err()); // vocab = 128
        assert!(server.submit(vec![127; 12]).is_ok());
    }

    #[test]
    fn generate_extends_prompt() {
        let server = tiny_server(4, Duration::from_micros(100));
        let out = server.generate(&[5, 6, 7], 3).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(&out[..3], &[5, 6, 7]);
        assert!(out.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn protocol_lines_roundtrip() {
        let server = tiny_server(4, Duration::from_micros(100));
        let resp = handle_request_line(&server, "{\"tokens\": [1, 2, 3]}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.req("len").unwrap().as_usize().unwrap(), 3);
        assert!(j.req("next").unwrap().as_usize().unwrap() < 128);
        let resp = handle_request_line(&server, "{\"prompt\": [4, 5], \"steps\": 2}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 4);
        // malformed requests come back as error lines, not panics
        for bad in ["nonsense", "{}", "{\"tokens\": [99999]}", "{\"tokens\": []}"] {
            let resp = handle_request_line(&server, bad);
            assert!(
                Json::parse(&resp).unwrap().get("error").is_some(),
                "{bad} must error"
            );
        }
    }

    #[test]
    fn load_test_reports_consistent_counters() {
        let server = tiny_server(4, Duration::from_micros(200));
        let rep = load_test(&server, 3, 4, 7).unwrap();
        assert_eq!(rep.requests, 12);
        assert!(rep.total_tokens >= 12 * 4);
        assert!(rep.throughput_tok_s > 0.0);
        assert!(rep.p50_ms <= rep.p90_ms && rep.p90_ms <= rep.p99_ms);
        assert!(rep.batches >= 3 && rep.batches <= 12);
        assert!(rep.max_batch <= 4);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.tokens, rep.total_tokens);
    }
}
