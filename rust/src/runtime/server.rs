//! Continuous-batching inference server over a prepacked `.wsic` model
//! — the serving path of the reproduction (iteration-level scheduling à
//! la Orca/vLLM, scaled to this repo's CPU substrate).
//!
//! # Decode-path architecture
//!
//! The batcher thread maintains a set of **in-flight sequences**, each
//! owning a [`KvCache`], and runs a scheduling iteration in a loop:
//!
//! 1. **Admit** — pop queued requests FIFO while the iteration has
//!    prefill rows free (up to `WATERSIC_SERVE_BATCH` rows, shared with
//!    re-prefills of slid windows), generation slots free, and KV-cache
//!    budget left (`WATERSIC_SERVE_KV_BUDGET` bytes across all
//!    in-flight sequences; a request whose cache could never fit is
//!    rejected with a clean error instead of risking OOM).
//! 2. **Prefill** — one batched [`prefill_packed`] over the admitted
//!    score windows, new generations' prompt windows, and any in-flight
//!    sequence whose window slid past `ctx` (its cached positions are
//!    stale, so it re-prefills — the O(t²) fallback the old re-score
//!    loop paid on every step).  Scores are answered from this forward;
//!    generations take their first token from it.
//! 3. **Decode** — one shared batched [`decode_packed`] step over every
//!    other active sequence: only the new token's projections run, and
//!    attention reads the cached K/V — O(t) per token instead of the
//!    re-score loop's O(t²).
//! 4. **Complete** — sequences that produced their last token send
//!    their [`GenOut`] and free their slot and KV bytes *immediately*;
//!    the next iteration's admission sees the freed capacity.
//!
//! Sequences therefore join and leave at **step** granularity: a score
//! request rides the next iteration's prefill even while long
//! generations are mid-flight, and every active sequence advances
//! exactly one token per iteration (the tests pin both).
//!
//! Why co-batching preserves bits: attention is causal within each
//! window, RoPE positions are window-relative, and the prepacked GEMM
//! entries fix every output row's reduction order independently of the
//! batch row count (see [`crate::linalg::gemm::PrepackedB`]).  A
//! request's response — and every decode step of a generation — is
//! therefore **bit-identical** no matter which batch it rides in, how
//! many co-batched requests surround it, or how many worker threads run
//! the kernels; the serve parity tests pin this against the full
//! re-score oracle.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::util::sync::{classes, TrackedCondvar, TrackedMutex, TrackedMutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context as _, Result};

use crate::coordinator::container::Container;
use crate::linalg::gemm::Precision;
use crate::linalg::Mat;
use crate::model::transformer::{
    argmax_last, decode_packed, prefill_packed, ForwardOpts, KvCache,
};
use crate::model::weights::{PackedWeights, Weights};
use crate::model::ModelConfig;
use crate::util::json::{obj, Json};

/// The `WATERSIC_SERVE_BATCH` engine option: max prefill rows per
/// batched forward and max concurrently active generations.  Default 8,
/// minimum 1 (no batching).
pub fn serve_batch_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_SERVE_BATCH")
        .map(|n| n.max(1))
        .unwrap_or(8)
}

/// The `WATERSIC_SERVE_FLUSH_US` engine option: how long (µs) the
/// batcher holds a partial batch open for co-arriving requests before
/// flushing it (only while no sequence is in flight — once decoding,
/// iterations run back to back).  Default 500µs; 0 flushes immediately.
pub fn serve_flush_us_from_env() -> u64 {
    crate::util::env::parsed::<u64>("WATERSIC_SERVE_FLUSH_US").unwrap_or(500)
}

/// The `WATERSIC_SERVE_KV_BUDGET` engine option: total bytes of KV
/// cache the scheduler may hold across all in-flight generations
/// (admission control — over-budget requests wait in the queue, and a
/// request that could never fit is rejected outright).  Default 1 GiB.
pub fn serve_kv_budget_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_SERVE_KV_BUDGET")
        .map(|n| n.max(1))
        .unwrap_or(1 << 30)
}

/// The `WATERSIC_SERVE_MAX_STEPS` engine option: per-request cap on
/// generation steps — an unbounded generate request would otherwise
/// hold a batcher slot (and its KV bytes) forever.  Default 256.
pub fn serve_max_steps_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_SERVE_MAX_STEPS")
        .map(|n| n.max(1))
        .unwrap_or(256)
}

/// The `WATERSIC_SERVE_QUEUE` engine option: bounded admission-queue
/// depth.  A submit that finds the queue full is shed immediately with
/// [`SubmitError::Overloaded`] (and a `retry_after_ms` estimate)
/// instead of queueing unboundedly.  Default 64, minimum 1.
pub fn serve_queue_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_SERVE_QUEUE")
        .map(|n| n.max(1))
        .unwrap_or(64)
}

/// The `WATERSIC_SERVE_DEADLINE_MS` engine option: default per-request
/// deadline.  Expired requests are cancelled at step granularity —
/// while queued they error cleanly; mid-generation they return their
/// partial tokens with [`GenOut::cancelled`] set and free their KV
/// bytes.  Default 0 = no deadline; a per-request `"deadline_ms"`
/// protocol field overrides it either way.
pub fn serve_deadline_from_env() -> Option<Duration> {
    match crate::util::env::parsed::<u64>("WATERSIC_SERVE_DEADLINE_MS") {
        Some(0) | None => None,
        Some(ms) => Some(Duration::from_millis(ms)),
    }
}

/// The `WATERSIC_SERVE_WEIGHTS` engine option: which resident form the
/// projection weights take at serving time.  `dequant` (the default)
/// eagerly reconstructs full-precision packed panels at load;  `coded`
/// keeps the container's quantized codes resident bit-packed and
/// dequantizes per KC block inside the GEMM pack stage.  The two modes
/// answer every request **byte-identically** — `matmul_coded` is
/// bit-for-bit equal to `matmul_prepacked` over the eager dequant — so
/// the knob only trades resident weight bytes against decode compute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeWeights {
    Dequant,
    Coded,
}

impl ServeWeights {
    pub fn from_env() -> ServeWeights {
        match crate::util::env::string("WATERSIC_SERVE_WEIGHTS").as_deref() {
            Some("coded") => ServeWeights::Coded,
            Some("dequant") | None => ServeWeights::Dequant,
            Some(other) => {
                eprintln!(
                    "[serve] unrecognized WATERSIC_SERVE_WEIGHTS={other:?} \
                     (expected dequant or coded); using dequant"
                );
                ServeWeights::Dequant
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// max prefill rows per batched forward, and max concurrently
    /// active generations
    pub batch_max: usize,
    /// deadline a partial batch is held open for (idle server only)
    pub flush: Duration,
    /// KV-cache byte budget across all in-flight generations
    pub kv_budget: usize,
    /// per-request generation-step cap
    pub max_steps: usize,
    /// bounded admission-queue depth (beyond it, submits shed)
    pub queue_max: usize,
    /// default per-request deadline (`None` = none)
    pub deadline: Option<Duration>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            batch_max: serve_batch_from_env(),
            flush: Duration::from_micros(serve_flush_us_from_env()),
            kv_budget: serve_kv_budget_from_env(),
            max_steps: serve_max_steps_from_env(),
            queue_max: serve_queue_from_env(),
            deadline: serve_deadline_from_env(),
        }
    }
}

/// Why a typed submit ([`Server::try_submit_score`] /
/// [`Server::try_submit_generate`]) refused a request.  A dedicated
/// error type (not a flattened `anyhow` chain) so the front door can
/// distinguish *shed because overloaded* — which becomes the
/// `{"error":"overloaded","retry_after_ms":N}` protocol response —
/// from a request that is simply invalid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// admission queue full: retry after the estimated drain time
    Overloaded { retry_after_ms: u64 },
    /// invalid request, over-budget request, or server shutting down
    Rejected(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded; retry after {retry_after_ms} ms")
            }
            SubmitError::Rejected(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Response to one scoring request.
#[derive(Clone, Debug)]
pub struct ScoreOut {
    /// logits at the last real token of the window (vocab-sized) —
    /// enough for greedy/sampled continuation and parity checks
    pub logits_last: Vec<f64>,
    /// mean next-token NLL over the window, nats (0.0 when len < 2)
    pub nll: f64,
    /// real (unpadded) window length
    pub len: usize,
    /// how many rows rode in the same prefill batch (telemetry)
    pub batched_with: usize,
    /// scheduler iteration that served this request — the
    /// step-granularity tests compare it against a co-batched
    /// generation's [`GenOut::start_iteration`]/`done_iteration` span
    pub iteration: usize,
}

impl ScoreOut {
    /// Greedy next token (ties keep the last index, matching
    /// [`crate::model::transformer::greedy_continuation`]).
    pub fn argmax(&self) -> usize {
        argmax_last(&self.logits_last)
    }
}

/// Response to one generation request.
#[derive(Clone, Debug)]
pub struct GenOut {
    /// prompt followed by the generated continuation
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// ms from submit to the first generated token (queueing + prefill)
    pub ttft_ms: f64,
    /// inter-token gaps (ms) for every token after the first
    pub itl_ms: Vec<f64>,
    /// scheduler iteration that prefilled this sequence
    pub start_iteration: usize,
    /// scheduler iteration that produced the final token
    pub done_iteration: usize,
    /// the sequence was cancelled (deadline expiry) before finishing
    /// its requested steps; `tokens` holds the partial continuation
    pub cancelled: bool,
}

impl GenOut {
    /// Generated (non-prompt) tokens.
    pub fn steps(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

enum Pending {
    Score {
        tokens: Vec<i32>,
        resp: mpsc::Sender<Result<ScoreOut>>,
        deadline: Option<Instant>,
    },
    Gen {
        prompt: Vec<i32>,
        steps: usize,
        resp: mpsc::Sender<Result<GenOut>>,
        submitted: Instant,
        deadline: Option<Instant>,
        cancel: Arc<AtomicBool>,
    },
}

/// `true` once a deadline has passed.
fn expired(deadline: Option<Instant>, now: Instant) -> bool {
    deadline.is_some_and(|d| now >= d)
}

struct Queue {
    q: VecDeque<Pending>,
    shutdown: bool,
}

/// One in-flight generation: its token history, remaining steps, and
/// its KV cache (taken out while a slid window re-prefills).
struct Active {
    toks: Vec<i32>,
    prompt_len: usize,
    steps_left: usize,
    /// `None` only for single-step generations (they never decode, so
    /// they skip cache allocation and KV accounting entirely)
    cache: Option<KvCache>,
    kv_bytes: usize,
    resp: mpsc::Sender<Result<GenOut>>,
    submitted: Instant,
    last_tok: Instant,
    ttft_ms: f64,
    itl_ms: Vec<f64>,
    start_iteration: usize,
    /// iteration at which this sequence last advanced a token (0 =
    /// never) — each iteration advances every active exactly once
    advanced_iter: usize,
    deadline: Option<Instant>,
    /// set by the client side (handle drop, connection death) — the
    /// reap sweep frees the slot and KV bytes at the next iteration
    cancel: Arc<AtomicBool>,
}

impl Active {
    fn needs_reslide(&self) -> bool {
        self.cache.as_ref().is_some_and(|c| c.is_full())
    }
}

/// Cumulative server counters (monotone; snapshot-diff around a run to
/// measure it in isolation).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServeStats {
    pub requests: usize,
    /// batched forwards issued (prefill + decode)
    pub batches: usize,
    /// real (unpadded) tokens forwarded
    pub tokens: usize,
    pub max_batch: usize,
    /// rows that went through prefill forwards
    pub prefill_rows: usize,
    /// shared batched decode forwards issued
    pub decode_steps: usize,
    /// tokens produced by decode forwards
    pub decode_tokens: usize,
    /// generation requests completed
    pub gen_completed: usize,
    /// sequences cancelled before completion (client gone or deadline
    /// expired), their slot and KV bytes freed at the next iteration
    pub gen_cancelled: usize,
    /// submits shed at admission because the bounded queue was full
    pub shed: usize,
    /// high-water mark of in-flight KV cache bytes
    pub kv_peak_bytes: usize,
}

struct Inner {
    cfg: ModelConfig,
    model: PackedWeights,
    opts: ServeOpts,
    queue: TrackedMutex<Queue>,
    cv: TrackedCondvar,
    requests: AtomicUsize,
    batches: AtomicUsize,
    tokens: AtomicUsize,
    max_batch: AtomicUsize,
    prefill_rows: AtomicUsize,
    decode_steps: AtomicUsize,
    decode_tokens: AtomicUsize,
    gen_completed: AtomicUsize,
    gen_cancelled: AtomicUsize,
    shed: AtomicUsize,
    kv_peak_bytes: AtomicUsize,
    /// EWMA of scheduler-iteration wall time in µs (retry-after
    /// estimates); 0 until the first iteration completes
    iter_ewma_us: AtomicU64,
}

impl Inner {
    /// Lock the admission queue.  Poison recovery now lives in the
    /// tracked wrapper (the tree-wide policy, `util::sync` module
    /// docs): every critical section here is a single push/pop/flag
    /// update, so a peer that panicked while holding the lock still
    /// left the queue consistent — cascading its panic into every
    /// client thread would only bury the original failure.
    fn lock_queue(&self) -> TrackedMutexGuard<'_, Queue> {
        self.queue.lock()
    }

    /// Retry-after estimate for a shed request: roughly how long a
    /// queue of `depth` takes to drain at the measured per-iteration
    /// pace (1 ms per iteration until the EWMA warms up).
    fn retry_after_ms(&self, depth: usize) -> u64 {
        let per_iter_ms = (self.iter_ewma_us.load(Ordering::Relaxed) / 1000).max(1);
        let iterations = (depth / self.opts.batch_max.max(1) + 1) as u64;
        (iterations * per_iter_ms).max(1)
    }
}

/// In-flight request handle; [`ScoreHandle::wait`] blocks for the
/// batched response.
pub struct ScoreHandle {
    rx: mpsc::Receiver<Result<ScoreOut>>,
}

impl ScoreHandle {
    pub fn wait(self) -> Result<ScoreOut> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("serve request dropped before completion"))?
    }

    /// Non-blocking poll (the reactor's per-tick drain).  `Some` is
    /// final: the response (or the dropped-channel error) is consumed.
    pub fn try_wait(&self) -> Option<Result<ScoreOut>> {
        match self.rx.try_recv() {
            Ok(v) => Some(v),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("serve request dropped before completion")))
            }
        }
    }
}

/// In-flight generation handle; [`GenHandle::wait`] blocks until the
/// sequence completes (or is rejected by admission control).
///
/// Dropping the handle without waiting **cancels** the generation: the
/// scheduler reaps the sequence at its next iteration and frees its
/// slot and KV bytes — a client that gave up (or a connection that
/// died) no longer burns decode steps to completion.
pub struct GenHandle {
    rx: mpsc::Receiver<Result<GenOut>>,
    cancel: Arc<AtomicBool>,
}

impl GenHandle {
    pub fn wait(self) -> Result<GenOut> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("generate request dropped before completion"))?
    }

    /// Non-blocking poll (the reactor's per-tick drain).  `Some` is
    /// final: the response (or the dropped-channel error) is consumed.
    pub fn try_wait(&self) -> Option<Result<GenOut>> {
        match self.rx.try_recv() {
            Ok(v) => Some(v),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("generate request dropped before completion")))
            }
        }
    }

    /// Cancel the generation without dropping the handle; the
    /// scheduler frees its slot and KV bytes at the next iteration.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

impl Drop for GenHandle {
    fn drop(&mut self) {
        // completed sequences already left the scheduler; for the rest
        // this is the disconnect-cancels-the-sequence path
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// The serving engine: owns the prepacked model and the batcher
/// thread.  Cheap to share behind an `Arc` (all methods take `&self`);
/// dropping it drains the queue and joins the batcher.
pub struct Server {
    inner: Arc<Inner>,
    batcher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving a prepacked model.
    pub fn start(cfg: ModelConfig, model: PackedWeights, opts: ServeOpts) -> Server {
        let inner = Arc::new(Inner {
            cfg,
            model,
            opts,
            queue: TrackedMutex::new(
                &classes::SERVE_QUEUE,
                Queue {
                    q: VecDeque::new(),
                    shutdown: false,
                },
            ),
            cv: TrackedCondvar::new(),
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            tokens: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
            prefill_rows: AtomicUsize::new(0),
            decode_steps: AtomicUsize::new(0),
            decode_tokens: AtomicUsize::new(0),
            gen_completed: AtomicUsize::new(0),
            gen_cancelled: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            kv_peak_bytes: AtomicUsize::new(0),
            iter_ewma_us: AtomicU64::new(0),
        });
        let worker = inner.clone();
        let batcher = std::thread::Builder::new()
            .name("watersic-serve-batcher".to_string())
            .spawn(move || batcher_loop(&worker))
            // lint:allow(no-panic-untrusted) — thread-spawn failure at
            // startup, before any request input exists
            .expect("spawning serve batcher");
        Server {
            inner,
            batcher: Some(batcher),
        }
    }

    /// Load path: build the serving representation from a `.wsic`
    /// container over the base weights and start serving.  The weight
    /// residency mode comes from the `WATERSIC_SERVE_WEIGHTS` engine
    /// option; both modes produce bit-identical responses (see
    /// [`ServeWeights`]).
    pub fn from_container(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
        opts: ServeOpts,
    ) -> Result<Server> {
        Self::from_container_mode(cfg, base, container, prec, ServeWeights::from_env(), opts)
    }

    /// [`Server::from_container`] with the weight residency mode pinned
    /// explicitly — the parity suites and the coded-serve CI job run
    /// both modes over one request log and diff every response byte.
    pub fn from_container_mode(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
        mode: ServeWeights,
        opts: ServeOpts,
    ) -> Result<Server> {
        let packed = match mode {
            ServeWeights::Dequant => {
                PackedWeights::from_container(cfg, base, container, prec)?
            }
            ServeWeights::Coded => {
                PackedWeights::from_container_coded(cfg, base, container, prec)?
            }
        };
        Ok(Server::start(cfg.clone(), packed, opts))
    }

    fn validate_tokens(&self, tokens: &[i32]) -> Result<()> {
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < self.inner.cfg.vocab,
                "token {t} outside vocab {}",
                self.inner.cfg.vocab
            );
        }
        Ok(())
    }

    /// Effective deadline: the explicit per-request one, else the
    /// server default (`WATERSIC_SERVE_DEADLINE_MS`).
    fn effective_deadline(&self, deadline: Option<Instant>) -> Option<Instant> {
        deadline.or_else(|| self.inner.opts.deadline.map(|d| Instant::now() + d))
    }

    /// Enqueue a scoring request (returns immediately).
    pub fn submit(&self, tokens: Vec<i32>) -> Result<ScoreHandle> {
        Ok(self.try_submit_score(tokens, None)?)
    }

    /// Typed admission path for the front door: validates, applies the
    /// bounded-queue admission control, and distinguishes *shed* from
    /// *invalid* in the error.  `deadline` overrides the server-wide
    /// default.
    pub fn try_submit_score(
        &self,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<ScoreHandle, SubmitError> {
        let valid = (|| -> Result<()> {
            ensure!(!tokens.is_empty(), "empty token window");
            ensure!(
                tokens.len() <= self.inner.cfg.ctx,
                "window of {} exceeds ctx {}",
                tokens.len(),
                self.inner.cfg.ctx
            );
            self.validate_tokens(&tokens)
        })();
        if let Err(e) = valid {
            return Err(SubmitError::Rejected(format!("{e:#}")));
        }
        let deadline = self.effective_deadline(deadline);
        let (tx, rx) = mpsc::channel();
        {
            let mut g = self.inner.lock_queue();
            if g.shutdown {
                return Err(SubmitError::Rejected(
                    "server is shutting down".to_string(),
                ));
            }
            if g.q.len() >= self.inner.opts.queue_max {
                self.inner.shed.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Overloaded {
                    retry_after_ms: self.inner.retry_after_ms(g.q.len()),
                });
            }
            g.q.push_back(Pending::Score {
                tokens,
                resp: tx,
                deadline,
            });
        }
        self.inner.requests.fetch_add(1, Ordering::Relaxed);
        self.inner.cv.notify_all();
        Ok(ScoreHandle { rx })
    }

    /// Score a window, blocking for the batched response.
    pub fn score(&self, tokens: Vec<i32>) -> Result<ScoreOut> {
        self.submit(tokens)?.wait()
    }

    /// Enqueue a greedy generation (returns immediately).  The sequence
    /// joins the scheduler at the next iteration, decodes one token per
    /// iteration through its KV cache, and leaves the instant it
    /// finishes.  `steps` is capped at `ServeOpts::max_steps`
    /// (`WATERSIC_SERVE_MAX_STEPS`) so a runaway request cannot hold a
    /// slot forever.
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        steps: usize,
    ) -> Result<GenHandle> {
        Ok(self.try_submit_generate(prompt, steps, None)?)
    }

    /// Typed admission path for the front door (see
    /// [`Server::try_submit_score`]).
    pub fn try_submit_generate(
        &self,
        prompt: Vec<i32>,
        steps: usize,
        deadline: Option<Instant>,
    ) -> Result<GenHandle, SubmitError> {
        let valid = (|| -> Result<()> {
            ensure!(!prompt.is_empty(), "empty prompt");
            ensure!(steps >= 1, "generate needs at least one step");
            ensure!(
                steps <= self.inner.opts.max_steps,
                "steps {} exceeds the per-request cap {} (WATERSIC_SERVE_MAX_STEPS)",
                steps,
                self.inner.opts.max_steps
            );
            self.validate_tokens(&prompt)
        })();
        if let Err(e) = valid {
            return Err(SubmitError::Rejected(format!("{e:#}")));
        }
        let deadline = self.effective_deadline(deadline);
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel();
        {
            let mut g = self.inner.lock_queue();
            if g.shutdown {
                return Err(SubmitError::Rejected(
                    "server is shutting down".to_string(),
                ));
            }
            if g.q.len() >= self.inner.opts.queue_max {
                self.inner.shed.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Overloaded {
                    retry_after_ms: self.inner.retry_after_ms(g.q.len()),
                });
            }
            g.q.push_back(Pending::Gen {
                prompt,
                steps,
                resp: tx,
                submitted: Instant::now(),
                deadline,
                cancel: cancel.clone(),
            });
        }
        self.inner.requests.fetch_add(1, Ordering::Relaxed);
        self.inner.cv.notify_all();
        Ok(GenHandle { rx, cancel })
    }

    /// Greedy continuation, blocking for the full sequence with decode
    /// telemetry (TTFT, inter-token gaps, scheduler iteration span).
    pub fn generate_timed(&self, prompt: &[i32], steps: usize) -> Result<GenOut> {
        self.submit_generate(prompt.to_vec(), steps)?.wait()
    }

    /// Greedy continuation, blocking for the tokens.
    pub fn generate(&self, prompt: &[i32], steps: usize) -> Result<Vec<i32>> {
        if steps == 0 {
            ensure!(!prompt.is_empty(), "empty prompt");
            self.validate_tokens(prompt)?;
            return Ok(prompt.to_vec());
        }
        Ok(self.generate_timed(prompt, steps)?.tokens)
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.inner.requests.load(Ordering::Relaxed),
            batches: self.inner.batches.load(Ordering::Relaxed),
            tokens: self.inner.tokens.load(Ordering::Relaxed),
            max_batch: self.inner.max_batch.load(Ordering::Relaxed),
            prefill_rows: self.inner.prefill_rows.load(Ordering::Relaxed),
            decode_steps: self.inner.decode_steps.load(Ordering::Relaxed),
            decode_tokens: self.inner.decode_tokens.load(Ordering::Relaxed),
            gen_completed: self.inner.gen_completed.load(Ordering::Relaxed),
            gen_cancelled: self.inner.gen_cancelled.load(Ordering::Relaxed),
            shed: self.inner.shed.load(Ordering::Relaxed),
            kv_peak_bytes: self.inner.kv_peak_bytes.load(Ordering::Relaxed),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.inner.cfg
    }

    /// Overload retry hint (the `retry_after_ms` protocol field) for
    /// sheds decided *outside* the scheduler — e.g. the front door's
    /// connection cap — using the current queue depth and the measured
    /// per-iteration pace.
    pub fn retry_after_hint_ms(&self) -> u64 {
        let depth = self.inner.lock_queue().q.len();
        self.inner.retry_after_ms(depth)
    }

    pub fn opts(&self) -> &ServeOpts {
        &self.inner.opts
    }

    /// Bytes held by the resident projection weights (load-time
    /// telemetry): eager panels and/or bit-packed coded planes.
    pub fn packed_bytes(&self) -> usize {
        self.inner.model.packed_bytes()
    }

    /// Projections serving straight from quantized codes (0 in
    /// `dequant` mode).
    pub fn coded_count(&self) -> usize {
        self.inner.model.coded_count()
    }

    /// Drain the queue, stop the batcher, and return the final
    /// counters.  Also runs on drop (without the counters).
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        {
            let mut g = self.inner.lock_queue();
            g.shutdown = true;
        }
        self.inner.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Admission decision for the request at the head of the queue.
enum Admit {
    Score,
    Gen { need: usize },
    Reject { need: usize },
    /// head is cancelled or past its deadline: drop it cleanly
    Drop,
    Stop,
}

/// Remove cancelled and deadline-expired sequences (before admission,
/// so the freed slots and KV bytes re-admit queued work this very
/// iteration): cancelled sequences close silently — the client is
/// gone — while expired ones return their partial tokens with
/// [`GenOut::cancelled`] set.
fn reap(
    inner: &Inner,
    active: &mut Vec<Active>,
    kv_in_flight: &mut usize,
    iteration: usize,
) {
    let now = Instant::now();
    let mut i = 0;
    while i < active.len() {
        let dead = active[i].cancel.load(Ordering::Relaxed);
        let late = expired(active[i].deadline, now);
        if !(dead || late) {
            i += 1;
            continue;
        }
        let act = active.swap_remove(i);
        *kv_in_flight -= act.kv_bytes;
        inner.gen_cancelled.fetch_add(1, Ordering::Relaxed);
        if dead {
            let _ = act.resp.send(Err(anyhow!("generation cancelled")));
        } else {
            let _ = act.resp.send(Ok(GenOut {
                tokens: act.toks,
                prompt_len: act.prompt_len,
                ttft_ms: act.ttft_ms,
                itl_ms: act.itl_ms,
                start_iteration: act.start_iteration,
                done_iteration: iteration,
                cancelled: true,
            }));
        }
    }
}

fn batcher_loop(inner: &Inner) {
    let mut active: Vec<Active> = Vec::new();
    let mut kv_in_flight: usize = 0;
    let mut iteration: usize = 0;
    loop {
        iteration += 1;
        reap(inner, &mut active, &mut kv_in_flight, iteration);
        // slid windows must re-prefill this iteration; they occupy
        // prefill rows before any new admission
        let reslide_rows = active.iter().filter(|a| a.needs_reslide()).count();
        let free_rows = inner.opts.batch_max.saturating_sub(reslide_rows);
        let mut picked: Vec<Pending> = Vec::new();
        {
            let mut g = inner.lock_queue();
            if active.is_empty() {
                loop {
                    if !g.q.is_empty() {
                        break;
                    }
                    if g.shutdown {
                        return;
                    }
                    g = inner.cv.wait(g);
                }
                // deadline-based coalescing: hold the partial batch
                // open a short window for co-arriving requests (only
                // while idle — an active scheduler never waits)
                let deadline = Instant::now() + inner.opts.flush;
                while g.q.len() < inner.opts.batch_max && !g.shutdown {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (ng, _) = inner.cv.wait_timeout(g, deadline - now);
                    g = ng;
                }
            }
            // strict-FIFO admission at step granularity
            let mut rows = 0usize;
            let mut slots = active.len();
            let now = Instant::now();
            loop {
                let decision = match g.q.front() {
                    None => Admit::Stop,
                    Some(Pending::Score { deadline, .. })
                        if expired(*deadline, now) =>
                    {
                        Admit::Drop
                    }
                    Some(Pending::Gen {
                        deadline, cancel, ..
                    }) if cancel.load(Ordering::Relaxed)
                        || expired(*deadline, now) =>
                    {
                        Admit::Drop
                    }
                    Some(Pending::Score { .. }) => {
                        if rows < free_rows {
                            Admit::Score
                        } else {
                            Admit::Stop
                        }
                    }
                    Some(Pending::Gen { prompt, steps, .. }) => {
                        let w0 = prompt.len().min(inner.cfg.ctx);
                        let cap = inner.cfg.ctx.min(w0 + steps - 1);
                        let need = if *steps > 1 {
                            KvCache::bytes_for(&inner.cfg, cap)
                        } else {
                            0
                        };
                        if need > inner.opts.kv_budget {
                            Admit::Reject { need }
                        } else if rows < free_rows
                            && slots < inner.opts.batch_max
                            && kv_in_flight + need <= inner.opts.kv_budget
                        {
                            Admit::Gen { need }
                        } else {
                            // out of rows/slots/KV budget this iteration;
                            // in-flight sequences finishing will free them
                            Admit::Stop
                        }
                    }
                };
                match decision {
                    Admit::Stop => break,
                    Admit::Score => {
                        rows += 1;
                        // admission matched `front()`: head is present
                        if let Some(p) = g.q.pop_front() {
                            picked.push(p);
                        }
                    }
                    Admit::Gen { need } => {
                        rows += 1;
                        slots += 1;
                        kv_in_flight += need;
                        // admission matched `front()`: head is present
                        if let Some(p) = g.q.pop_front() {
                            picked.push(p);
                        }
                    }
                    Admit::Reject { need } => {
                        // could never run under this budget: clean
                        // error instead of OOM or a wedged queue
                        if let Some(Pending::Gen { resp, .. }) = g.q.pop_front() {
                            let _ = resp.send(Err(anyhow!(
                                "generation needs a {need}-byte KV cache, over \
                                 the WATERSIC_SERVE_KV_BUDGET of {} bytes",
                                inner.opts.kv_budget
                            )));
                        }
                    }
                    Admit::Drop => match g.q.pop_front() {
                        Some(Pending::Score { resp, .. }) => {
                            let _ = resp
                                .send(Err(anyhow!("deadline exceeded while queued")));
                        }
                        Some(Pending::Gen { resp, cancel, .. }) => {
                            inner.gen_cancelled.fetch_add(1, Ordering::Relaxed);
                            if !cancel.load(Ordering::Relaxed) {
                                let _ = resp.send(Err(anyhow!(
                                    "deadline exceeded while queued"
                                )));
                            }
                        }
                        None => {}
                    },
                }
            }
        }
        if picked.is_empty() && active.is_empty() {
            // woken with nothing admissible (e.g. every queued request
            // was rejected); re-enter the idle wait
            continue;
        }
        // a panicking forward must not kill the batcher; the in-flight
        // state may be mid-mutation, so drop every affected sequence
        // (their senders close, clients see an error) and start clean
        let t_iter = Instant::now();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_iteration(inner, &mut active, &mut kv_in_flight, iteration, picked)
        }));
        if res.is_err() {
            log::warn!(
                "serve iteration panicked; {} in-flight sequences dropped",
                active.len()
            );
            active.clear();
            kv_in_flight = 0;
        }
        // EWMA of iteration wall time, feeding retry-after estimates
        let us = t_iter.elapsed().as_micros().min(u64::MAX as u128) as u64;
        let prev = inner.iter_ewma_us.load(Ordering::Relaxed);
        let next = if prev == 0 { us.max(1) } else { (prev * 7 + us) / 8 };
        inner.iter_ewma_us.store(next, Ordering::Relaxed);
        inner.kv_peak_bytes.fetch_max(kv_in_flight, Ordering::Relaxed);
    }
}

/// Record one generated token on an active sequence.
fn advance(a: &mut Active, next: i32, iteration: usize, now: Instant) {
    if a.toks.len() == a.prompt_len {
        a.ttft_ms = now.duration_since(a.submitted).as_secs_f64() * 1e3;
    } else {
        a.itl_ms
            .push(now.duration_since(a.last_tok).as_secs_f64() * 1e3);
    }
    a.last_tok = now;
    a.toks.push(next);
    a.steps_left -= 1;
    a.advanced_iter = iteration;
}

/// One scheduling iteration: batched prefill (admitted requests + slid
/// windows), shared batched decode over everything else, completion
/// sweep.
fn run_iteration(
    inner: &Inner,
    active: &mut Vec<Active>,
    kv_in_flight: &mut usize,
    iteration: usize,
    picked: Vec<Pending>,
) {
    let cfg = &inner.cfg;
    if let Some(crate::util::fault::Fault::Panic) =
        crate::util::fault::check("sched")
    {
        // lint:allow(no-panic-untrusted) — deliberate fault-injection
        // site (fault-inject builds only); the batcher's catch_unwind
        // must contain it, which rust/tests/fault.rs pins
        panic!("injected scheduler fault (site sched)");
    }

    // ---- prefill batch
    enum Row {
        Score {
            tokens: Vec<i32>,
            resp: mpsc::Sender<Result<ScoreOut>>,
        },
        NewGen {
            act: Active,
            window: Vec<i32>,
        },
        Reslide {
            idx: usize,
            cache: KvCache,
            window: Vec<i32>,
        },
    }
    let mut rows: Vec<Row> = Vec::new();
    for (idx, a) in active.iter_mut().enumerate() {
        if a.needs_reslide() {
            // lint:allow(no-panic-untrusted) — scheduler invariant:
            // needs_reslide() implies an installed cache
            let cache = a.cache.take().unwrap();
            let t = cfg.ctx.min(a.toks.len());
            let window = a.toks[a.toks.len() - t..].to_vec();
            rows.push(Row::Reslide { idx, cache, window });
        }
    }
    for p in picked {
        match p {
            Pending::Score { tokens, resp, .. } => {
                rows.push(Row::Score { tokens, resp })
            }
            Pending::Gen {
                prompt,
                steps,
                resp,
                submitted,
                deadline,
                cancel,
            } => {
                let t = cfg.ctx.min(prompt.len());
                let window = prompt[prompt.len() - t..].to_vec();
                let (cache, kv_bytes) = if steps > 1 {
                    let cap = cfg.ctx.min(t + steps - 1);
                    (
                        Some(KvCache::new(cfg, cap)),
                        KvCache::bytes_for(cfg, cap),
                    )
                } else {
                    (None, 0)
                };
                let now = Instant::now();
                let act = Active {
                    prompt_len: prompt.len(),
                    toks: prompt,
                    steps_left: steps,
                    cache,
                    kv_bytes,
                    resp,
                    submitted,
                    last_tok: now,
                    ttft_ms: 0.0,
                    itl_ms: Vec::new(),
                    start_iteration: iteration,
                    advanced_iter: 0,
                    deadline,
                    cancel,
                };
                rows.push(Row::NewGen { act, window });
            }
        }
    }
    if !rows.is_empty() {
        let b = rows.len();
        let t_max = rows
            .iter()
            .map(|r| match r {
                Row::Score { tokens, .. } => tokens.len(),
                Row::NewGen { window, .. } | Row::Reslide { window, .. } => {
                    window.len()
                }
            })
            .max()
            .unwrap_or(0);
        // pad each window to the batch max with token 0: causal
        // attention and window-relative RoPE keep every row before the
        // pad bit-identical to the unpadded forward (module docs)
        let mut toks = Vec::with_capacity(b * t_max);
        let mut real_tokens = 0;
        for r in &rows {
            let w: &[i32] = match r {
                Row::Score { tokens, .. } => tokens,
                Row::NewGen { window, .. } | Row::Reslide { window, .. } => window,
            };
            real_tokens += w.len();
            toks.extend_from_slice(w);
            toks.resize(toks.len() + (t_max - w.len()), 0);
        }
        let mut kv: Vec<Option<(&mut KvCache, usize)>> = Vec::with_capacity(b);
        for r in rows.iter_mut() {
            kv.push(match r {
                Row::Score { .. } => None,
                Row::NewGen { act, window } => {
                    let wl = window.len();
                    act.cache.as_mut().map(|c| (c, wl))
                }
                Row::Reslide { cache, window, .. } => {
                    cache.clear();
                    Some((cache, window.len()))
                }
            });
        }
        let out = prefill_packed(
            cfg,
            &inner.model,
            &toks,
            b,
            t_max,
            &mut kv,
            &ForwardOpts::default(),
        );
        drop(kv);
        inner.batches.fetch_add(1, Ordering::Relaxed);
        inner.tokens.fetch_add(real_tokens, Ordering::Relaxed);
        inner.max_batch.fetch_max(b, Ordering::Relaxed);
        inner.prefill_rows.fetch_add(b, Ordering::Relaxed);
        let now = Instant::now();
        for (i, row) in rows.into_iter().enumerate() {
            let base = i * t_max;
            match row {
                Row::Score { tokens, resp } => {
                    let len = tokens.len();
                    let score = ScoreOut {
                        logits_last: out.logits.row(base + len - 1).to_vec(),
                        nll: window_nll(&out.logits, base, &tokens),
                        len,
                        batched_with: b,
                        iteration,
                    };
                    // a client that gave up (dropped its handle) is not
                    // an error
                    let _ = resp.send(Ok(score));
                }
                Row::NewGen { mut act, window } => {
                    let next =
                        argmax_last(out.logits.row(base + window.len() - 1));
                    advance(&mut act, next as i32, iteration, now);
                    active.push(act);
                }
                Row::Reslide { idx, cache, window } => {
                    let a = &mut active[idx];
                    a.cache = Some(cache);
                    let next =
                        argmax_last(out.logits.row(base + window.len() - 1));
                    advance(a, next as i32, iteration, now);
                }
            }
        }
    }

    // ---- shared batched decode over every sequence that didn't
    // advance via this iteration's prefill
    let mut dec_idx: Vec<usize> = Vec::new();
    let mut dec_toks: Vec<i32> = Vec::new();
    let mut dec_caches: Vec<&mut KvCache> = Vec::new();
    for (i, a) in active.iter_mut().enumerate() {
        if a.advanced_iter != iteration && a.steps_left > 0 {
            dec_idx.push(i);
            // lint:allow(no-panic-untrusted) — scheduler invariant: an
            // admitted generation holds a non-empty token list
            dec_toks.push(*a.toks.last().unwrap());
            // lint:allow(no-panic-untrusted) — scheduler invariant: a
            // sequence with steps_left > 0 holds a live KV cache
            let cache = a.cache.as_mut().expect("multi-step sequence without cache");
            dec_caches.push(cache);
        }
    }
    if !dec_caches.is_empty() {
        let width = dec_caches.len();
        let logits = decode_packed(cfg, &inner.model, &dec_toks, &mut dec_caches);
        drop(dec_caches);
        inner.batches.fetch_add(1, Ordering::Relaxed);
        inner.tokens.fetch_add(width, Ordering::Relaxed);
        inner.max_batch.fetch_max(width, Ordering::Relaxed);
        inner.decode_steps.fetch_add(1, Ordering::Relaxed);
        inner.decode_tokens.fetch_add(width, Ordering::Relaxed);
        let now = Instant::now();
        for (row, &i) in dec_idx.iter().enumerate() {
            let next = argmax_last(logits.row(row));
            advance(&mut active[i], next as i32, iteration, now);
        }
    }

    // ---- completion sweep: finished sequences free their slot and KV
    // bytes before the next iteration's admission runs
    let mut i = 0;
    while i < active.len() {
        if active[i].steps_left == 0 {
            let act = active.swap_remove(i);
            *kv_in_flight -= act.kv_bytes;
            inner.gen_completed.fetch_add(1, Ordering::Relaxed);
            let _ = act.resp.send(Ok(GenOut {
                tokens: act.toks,
                prompt_len: act.prompt_len,
                ttft_ms: act.ttft_ms,
                itl_ms: act.itl_ms,
                start_iteration: act.start_iteration,
                done_iteration: iteration,
                cancelled: false,
            }));
        } else {
            i += 1;
        }
    }
}

/// Mean next-token NLL (nats) of one window whose rows start at `base`
/// in the batched logits; 0.0 for single-token windows.
fn window_nll(logits: &Mat, base: usize, tokens: &[i32]) -> f64 {
    if tokens.len() < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    for pos in 0..tokens.len() - 1 {
        let row = logits.row(base + pos);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        total += lse - row[tokens[pos + 1] as usize];
    }
    total / (tokens.len() - 1) as f64
}

// ---------------------------------------------------------------------
// self-driving load test (the CI serve-smoke driver)

/// Workload shape for [`load_test`].
#[derive(Clone, Debug)]
pub struct LoadMix {
    /// fraction of requests that are generations (the rest score)
    pub generate_frac: f64,
    /// draw generation lengths from a heavy-tailed (Pareto-like)
    /// distribution — most requests short, a few near `max_steps` —
    /// instead of uniform
    pub heavy_tail: bool,
    /// longest generation a client asks for
    pub max_steps: usize,
}

impl Default for LoadMix {
    fn default() -> LoadMix {
        LoadMix {
            generate_frac: 0.0,
            heavy_tail: false,
            max_steps: 16,
        }
    }
}

/// Result of one [`load_test`] run.  Whole-request latency percentiles
/// cover score requests; generations report TTFT and inter-token
/// latency separately (a decode-dominated workload is invisible in
/// whole-request p99 — one 256-step generation is hundreds of fast
/// tokens, not one slow request).
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub clients: usize,
    pub requests: usize,
    pub score_requests: usize,
    pub gen_requests: usize,
    /// scored window tokens + generated tokens (client-visible work)
    pub total_tokens: usize,
    /// generated (non-prompt) tokens
    pub gen_tokens: usize,
    pub wall_secs: f64,
    /// client-visible tokens per second across all clients
    pub throughput_tok_s: f64,
    /// generated tokens per second
    pub gen_tok_s: f64,
    /// whole-request score latency percentiles (ms)
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    /// time-to-first-token percentiles over generations (ms)
    pub ttft_p50_ms: f64,
    pub ttft_p90_ms: f64,
    pub ttft_p99_ms: f64,
    /// inter-token latency percentiles over all generated gaps (ms)
    pub itl_p50_ms: f64,
    pub itl_p90_ms: f64,
    pub itl_p99_ms: f64,
    pub batches: usize,
    pub mean_batch: f64,
    pub max_batch: usize,
    /// shared batched decode forwards this run issued
    pub decode_steps: usize,
}

impl LoadReport {
    pub fn print(&self) {
        println!(
            "load test: {} clients x {} requests  ({} tokens, {:.2}s wall)",
            self.clients,
            self.requests / self.clients.max(1),
            self.total_tokens,
            self.wall_secs
        );
        println!("  throughput : {:.0} tok/s", self.throughput_tok_s);
        println!(
            "  score lat  : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms  ({} requests)",
            self.p50_ms, self.p90_ms, self.p99_ms, self.score_requests
        );
        if self.gen_requests > 0 {
            println!(
                "  generate   : {} requests, {} tokens ({:.0} tok/s, {} decode steps)",
                self.gen_requests, self.gen_tokens, self.gen_tok_s, self.decode_steps
            );
            println!(
                "  ttft       : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
                self.ttft_p50_ms, self.ttft_p90_ms, self.ttft_p99_ms
            );
            println!(
                "  itl        : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
                self.itl_p50_ms, self.itl_p90_ms, self.itl_p99_ms
            );
        }
        println!(
            "  batching   : {} batches (mean {:.2}, max {})",
            self.batches, self.mean_batch, self.max_batch
        );
    }
}

/// Sorted-percentile pick (0.0 when the sample is empty).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

#[derive(Default)]
struct ClientTally {
    /// (latency ms, window len, batched_with) per score request
    score_lat: Vec<(f64, usize, usize)>,
    ttft: Vec<f64>,
    itl: Vec<f64>,
    gen_requests: usize,
    gen_tokens: usize,
}

/// Drive the server with `clients` concurrent threads, each submitting
/// `per_client` requests over deterministic token windows of varying
/// length — score requests, or a [`LoadMix`]-controlled blend of
/// scores and greedy generations — and measure per-request score
/// latency, generation TTFT / inter-token latency, and end-to-end
/// token throughput.
pub fn load_test(
    server: &Server,
    clients: usize,
    per_client: usize,
    seed: u64,
    mix: &LoadMix,
) -> Result<LoadReport> {
    ensure!(clients >= 1 && per_client >= 1, "empty load test");
    ensure!(mix.max_steps >= 1, "load mix needs max_steps >= 1");
    let cfg = server.config();
    let (vocab, ctx) = (cfg.vocab, cfg.ctx);
    let max_steps = mix.max_steps.min(server.opts().max_steps);
    let before = server.stats();
    let t0 = Instant::now();
    let tallies: Vec<ClientTally> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<ClientTally> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut tally = ClientTally::default();
                    for _ in 0..per_client {
                        let is_gen = mix.generate_frac > 0.0
                            && (rng.below(1_000_000) as f64)
                                < mix.generate_frac * 1e6;
                        if is_gen {
                            let plen = 4 + rng.below((ctx / 2).max(1));
                            let plen = plen.min(ctx);
                            let prompt: Vec<i32> = (0..plen)
                                .map(|_| rng.below(vocab) as i32)
                                .collect();
                            let steps = if mix.heavy_tail {
                                // Pareto-like: P(steps > s) ~ s^-1.43
                                let u = (rng.below(1_000_000) + 1) as f64 / 1e6;
                                ((1.0 / u).powf(0.7).ceil() as usize)
                                    .clamp(1, max_steps)
                            } else {
                                1 + rng.below(max_steps)
                            };
                            let out = server.generate_timed(&prompt, steps)?;
                            tally.gen_requests += 1;
                            tally.gen_tokens += out.steps();
                            tally.ttft.push(out.ttft_ms);
                            tally.itl.extend(out.itl_ms.iter().copied());
                        } else {
                            let len = 4 + rng.below(ctx.saturating_sub(3).max(1));
                            let len = len.min(ctx);
                            let tokens: Vec<i32> = (0..len)
                                .map(|_| rng.below(vocab) as i32)
                                .collect();
                            let t = Instant::now();
                            let score = server.score(tokens)?;
                            tally.score_lat.push((
                                t.elapsed().as_secs_f64() * 1e3,
                                score.len,
                                score.batched_with,
                            ));
                        }
                    }
                    Ok(tally)
                })
            })
            .collect();
        let mut all = Vec::new();
        let mut err = None;
        for h in handles {
            // lint:allow(no-panic-untrusted) — harness bug if a client
            // thread panics; re-raising it is the correct report
            match h.join().expect("load-test client panicked") {
                Ok(v) => all.push(v),
                Err(e) => err = Some(e),
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(all),
        }
    })?;
    let wall_secs = t0.elapsed().as_secs_f64();
    let after = server.stats();
    let score_tokens: usize = tallies
        .iter()
        .flat_map(|t| t.score_lat.iter())
        .map(|&(_, n, _)| n)
        .sum();
    let gen_tokens: usize = tallies.iter().map(|t| t.gen_tokens).sum();
    let gen_requests: usize = tallies.iter().map(|t| t.gen_requests).sum();
    let score_requests: usize = tallies.iter().map(|t| t.score_lat.len()).sum();
    // run-local, like batches/requests: derived from this run's own
    // responses, not the server-lifetime high-water mark
    let max_batch = tallies
        .iter()
        .flat_map(|t| t.score_lat.iter())
        .map(|&(_, _, b)| b)
        .max()
        .unwrap_or(0);
    let mut lats: Vec<f64> = tallies
        .iter()
        .flat_map(|t| t.score_lat.iter())
        .map(|&(l, _, _)| l)
        .collect();
    lats.sort_by(f64::total_cmp);
    let mut ttfts: Vec<f64> =
        tallies.iter().flat_map(|t| t.ttft.iter().copied()).collect();
    ttfts.sort_by(f64::total_cmp);
    let mut itls: Vec<f64> =
        tallies.iter().flat_map(|t| t.itl.iter().copied()).collect();
    itls.sort_by(f64::total_cmp);
    let batches = after.batches - before.batches;
    let total_tokens = score_tokens + gen_tokens;
    let requests = score_requests + gen_requests;
    Ok(LoadReport {
        clients,
        requests,
        score_requests,
        gen_requests,
        total_tokens,
        gen_tokens,
        wall_secs,
        throughput_tok_s: total_tokens as f64 / wall_secs.max(1e-9),
        gen_tok_s: gen_tokens as f64 / wall_secs.max(1e-9),
        p50_ms: pct(&lats, 0.5),
        p90_ms: pct(&lats, 0.9),
        p99_ms: pct(&lats, 0.99),
        ttft_p50_ms: pct(&ttfts, 0.5),
        ttft_p90_ms: pct(&ttfts, 0.9),
        ttft_p99_ms: pct(&ttfts, 0.99),
        itl_p50_ms: pct(&itls, 0.5),
        itl_p90_ms: pct(&itls, 0.9),
        itl_p99_ms: pct(&itls, 0.99),
        batches,
        mean_batch: requests as f64 / batches.max(1) as f64,
        max_batch,
        decode_steps: after.decode_steps - before.decode_steps,
    })
}

/// Result of one [`load_test_open`] run.  Open-loop offered load
/// (fixed arrival rate, not closed-loop request-after-response), so
/// shed fraction and *accepted*-request latency are the interesting
/// numbers: a server at 2x capacity should shed cleanly and keep the
/// accepted p99 bounded, not let queueing delay grow without limit.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered: usize,
    pub accepted: usize,
    pub shed: usize,
    pub errors: usize,
    pub wall_secs: f64,
    /// fraction of offered requests shed with `overloaded`
    pub shed_frac: f64,
    /// accepted-request whole-latency percentiles (ms)
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl OpenLoopReport {
    pub fn print(&self) {
        println!(
            "open-loop: {} offered over {:.2}s ({} accepted, {} shed [{:.0}%], {} errors)",
            self.offered,
            self.wall_secs,
            self.accepted,
            self.shed,
            self.shed_frac * 100.0,
            self.errors
        );
        println!(
            "  accepted lat: p50 {:.2} ms  p99 {:.2} ms",
            self.p50_ms, self.p99_ms
        );
    }
}

/// Offer score requests at a fixed rate for `duration`, regardless of
/// how fast responses come back (open loop).  A dispatcher thread
/// paces non-blocking [`Server::try_submit_score`] calls on a strict
/// interval; collector threads drain the accepted handles so slow
/// responses never delay the arrival process.  Overload sheds count
/// toward `shed_frac` rather than blocking.
pub fn load_test_open(
    server: &Server,
    offered_rps: f64,
    duration: Duration,
    seed: u64,
) -> Result<OpenLoopReport> {
    ensure!(offered_rps > 0.0, "open-loop rate must be positive");
    let cfg = server.config();
    let (vocab, ctx) = (cfg.vocab, cfg.ctx);
    let interval = Duration::from_secs_f64(1.0 / offered_rps);
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel::<(Instant, ScoreHandle)>();
    let rx = TrackedMutex::new(&classes::SERVE_LOADTEST, rx);
    let (mut offered, mut shed) = (0usize, 0usize);
    let mut lat_err: Vec<(Vec<f64>, usize)> = std::thread::scope(|scope| {
        let collectors: Vec<_> = (0..4)
            .map(|_| {
                scope.spawn(|| {
                    let (mut lats, mut errors) = (Vec::new(), 0usize);
                    loop {
                        let msg = {
                            let g = rx.lock();
                            g.recv()
                        };
                        let Ok((sent, handle)) = msg else { break };
                        match handle.wait() {
                            Ok(_) => {
                                lats.push(sent.elapsed().as_secs_f64() * 1e3)
                            }
                            Err(_) => errors += 1,
                        }
                    }
                    (lats, errors)
                })
            })
            .collect();
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x0BE7_0BE7);
        let mut next = t0;
        while t0.elapsed() < duration {
            let now = Instant::now();
            if now < next {
                std::thread::sleep(next - now);
            }
            next += interval;
            let len = (4 + rng.below(ctx.saturating_sub(3).max(1))).min(ctx);
            let tokens: Vec<i32> =
                (0..len).map(|_| rng.below(vocab) as i32).collect();
            offered += 1;
            match server.try_submit_score(tokens, None) {
                Ok(h) => {
                    let _ = tx.send((Instant::now(), h));
                }
                Err(SubmitError::Overloaded { .. }) => shed += 1,
                Err(SubmitError::Rejected(_)) => shed += 1,
            }
        }
        drop(tx);
        collectors
            .into_iter()
            // lint:allow(no-panic-untrusted) — harness bug if a
            // collector thread panics; re-raising is the right report
            .map(|h| h.join().expect("open-loop collector panicked"))
            .collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let errors: usize = lat_err.iter().map(|(_, e)| e).sum();
    let mut lats: Vec<f64> =
        lat_err.drain(..).flat_map(|(l, _)| l).collect();
    lats.sort_by(f64::total_cmp);
    let accepted = lats.len();
    Ok(OpenLoopReport {
        offered,
        accepted,
        shed,
        errors,
        wall_secs,
        shed_frac: shed as f64 / offered.max(1) as f64,
        p50_ms: pct(&lats, 0.5),
        p99_ms: pct(&lats, 0.99),
    })
}

// ---------------------------------------------------------------------
// line-JSON front door (the TCP protocol body, kept here so the lib
// tests cover it; main.rs only wires the sockets)

/// A request line accepted into the scheduler (or answered on the
/// spot).  The synchronous front door waits the handle; the reactor
/// polls it with `try_wait` so one slow generation never blocks the
/// event loop.
pub enum Submitted {
    /// answered inline: validation/parse error, overload shed, or the
    /// `steps: 0` prompt echo
    Ready(String),
    Score(ScoreHandle),
    Gen(GenHandle),
}

/// `{"error": msg}` as a compact protocol line.
pub fn error_line(msg: &str) -> String {
    obj(vec![("error", Json::Str(msg.to_string()))]).to_string_compact()
}

/// The load-shed protocol line:
/// `{"error":"overloaded","retry_after_ms":N}`.
pub fn overloaded_line(retry_after_ms: u64) -> String {
    obj(vec![
        ("error", Json::Str("overloaded".to_string())),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
    .to_string_compact()
}

fn submit_error_line(e: &SubmitError) -> String {
    match e {
        SubmitError::Overloaded { retry_after_ms } => {
            overloaded_line(*retry_after_ms)
        }
        SubmitError::Rejected(msg) => error_line(msg),
    }
}

/// Serialize a score response for the line protocol.
pub fn score_line(out: &ScoreOut) -> String {
    obj(vec![
        ("len", Json::Num(out.len as f64)),
        ("next", Json::Num(out.argmax() as f64)),
        ("nll", Json::Num(out.nll)),
        ("batched_with", Json::Num(out.batched_with as f64)),
    ])
    .to_string_compact()
}

/// Serialize a generation response for the line protocol (adds
/// `"cancelled": true` when a deadline cut the sequence short).
pub fn gen_line(out: &GenOut) -> String {
    let mut pairs = vec![
        (
            "tokens",
            Json::Arr(out.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("steps", Json::Num(out.steps() as f64)),
        ("ttft_ms", Json::Num(out.ttft_ms)),
    ];
    if out.cancelled {
        pairs.push(("cancelled", Json::Bool(true)));
    }
    obj(pairs).to_string_compact()
}

/// Parse one protocol line and submit it without blocking on the
/// response.  Requests:
///   `{"tokens": [..]}`               → `{"len", "next", "nll", "batched_with"}`
///   `{"prompt": [..], "steps": N}`   → `{"tokens": [..], "steps", "ttft_ms"}`
///     (`"max_tokens"` is accepted as an alias for `"steps"`; both are
///     capped at the server's `WATERSIC_SERVE_MAX_STEPS`)
/// Either form takes an optional `"deadline_ms"` field overriding the
/// server-wide `WATERSIC_SERVE_DEADLINE_MS` default.  Errors come back
/// as `{"error": "..."}` lines (overload sheds carry
/// `"retry_after_ms"`) — a malformed request never kills the
/// connection.
pub fn submit_request_line(server: &Server, line: &str) -> Submitted {
    match submit_request_inner(server, line) {
        Ok(s) => s,
        Err(e) => Submitted::Ready(error_line(&format!("{e:#}"))),
    }
}

fn parse_tokens(j: &Json) -> Result<Vec<i32>> {
    j.as_arr()?
        .iter()
        .map(|v| {
            let x = v.as_f64()?;
            ensure!(
                x.fract() == 0.0 && (0.0..2_147_483_648.0).contains(&x),
                "bad token {x}"
            );
            Ok(x as i32)
        })
        .collect()
}

fn submit_request_inner(server: &Server, line: &str) -> Result<Submitted> {
    let req = Json::parse(line).context("parsing request")?;
    let deadline = match req.get("deadline_ms") {
        Some(v) => {
            let ms = v.as_usize().context("bad deadline_ms")?;
            Some(Instant::now() + Duration::from_millis(ms as u64))
        }
        None => None,
    };
    if let Some(toks) = req.get("tokens") {
        let tokens = parse_tokens(toks)?;
        return Ok(match server.try_submit_score(tokens, deadline) {
            Ok(h) => Submitted::Score(h),
            Err(e) => Submitted::Ready(submit_error_line(&e)),
        });
    }
    if let Some(prompt) = req.get("prompt") {
        let steps = match req.get("steps").or_else(|| req.get("max_tokens")) {
            Some(s) => s.as_usize()?,
            None => 8,
        };
        let prompt = parse_tokens(prompt)?;
        if steps == 0 {
            // validated echo; never queues
            let toks = server.generate(&prompt, 0)?;
            return Ok(Submitted::Ready(
                obj(vec![(
                    "tokens",
                    Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect()),
                )])
                .to_string_compact(),
            ));
        }
        // the per-request step cap (WATERSIC_SERVE_MAX_STEPS) is
        // enforced by the submit path — an unbounded request errors
        // instead of monopolizing the batcher
        return Ok(match server.try_submit_generate(prompt, steps, deadline) {
            Ok(h) => Submitted::Gen(h),
            Err(e) => Submitted::Ready(submit_error_line(&e)),
        });
    }
    bail!("request needs \"tokens\" or \"prompt\"")
}

/// Handle one protocol line synchronously (submit + block for the
/// response) — the threaded front door and the lib tests use this;
/// the reactor uses [`submit_request_line`] directly.
pub fn handle_request_line(server: &Server, line: &str) -> String {
    match submit_request_line(server, line) {
        Submitted::Ready(s) => s,
        Submitted::Score(h) => match h.wait() {
            Ok(o) => score_line(&o),
            Err(e) => error_line(&format!("{e:#}")),
        },
        Submitted::Gen(h) => match h.wait() {
            Ok(o) => gen_line(&o),
            Err(e) => error_line(&format!("{e:#}")),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_server(batch_max: usize, flush: Duration) -> Server {
        tiny_server_opts(ServeOpts {
            batch_max,
            flush,
            kv_budget: 1 << 30,
            max_steps: 256,
            queue_max: 64,
            deadline: None,
        })
    }

    fn tiny_server_opts(opts: ServeOpts) -> Server {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 21);
        let pw = PackedWeights::new(&cfg, w, Precision::F64);
        Server::start(cfg, pw, opts)
    }

    #[test]
    fn score_returns_vocab_logits_and_counts() {
        let server = tiny_server(4, Duration::from_micros(200));
        let out = server.score(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(out.logits_last.len(), 128);
        assert_eq!(out.len, 4);
        assert!(out.batched_with >= 1);
        assert!(out.nll.is_finite());
        assert!(out.argmax() < 128);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.tokens, 4);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn submit_validates_windows() {
        let server = tiny_server(2, Duration::from_micros(0));
        assert!(server.submit(vec![]).is_err());
        assert!(server.submit(vec![0; 13]).is_err()); // ctx = 12
        assert!(server.submit(vec![-1]).is_err());
        assert!(server.submit(vec![128]).is_err()); // vocab = 128
        assert!(server.submit(vec![127; 12]).is_ok());
    }

    #[test]
    fn generate_extends_prompt() {
        let server = tiny_server(4, Duration::from_micros(100));
        let out = server.generate(&[5, 6, 7], 3).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(&out[..3], &[5, 6, 7]);
        assert!(out.iter().all(|&t| (0..128).contains(&t)));
        let stats = server.stats();
        assert_eq!(stats.gen_completed, 1);
        // 1 prefill token batch + decode steps for the later tokens
        assert!(stats.decode_tokens >= 1);
        assert!(stats.kv_peak_bytes > 0);
    }

    #[test]
    fn generate_steps_are_bounded() {
        // the max_steps rider: an unbounded request errors cleanly at
        // submit instead of holding a scheduler slot forever
        let server = tiny_server_opts(ServeOpts {
            batch_max: 4,
            flush: Duration::from_micros(100),
            kv_budget: 1 << 30,
            max_steps: 4,
            queue_max: 64,
            deadline: None,
        });
        let err = server.generate(&[1, 2], 5).unwrap_err().to_string();
        assert!(err.contains("cap"), "unexpected error: {err}");
        assert_eq!(server.generate(&[1, 2], 4).unwrap().len(), 6);
        // steps = 0 echoes the validated prompt without queueing
        assert_eq!(server.generate(&[1, 2], 0).unwrap(), vec![1, 2]);
        assert!(server.generate(&[999], 0).is_err());
    }

    #[test]
    fn kv_budget_rejects_oversized_requests() {
        // a budget below any multi-step cache: admission must reject
        // with a clean error, and scores (no KV) keep flowing
        let server = tiny_server_opts(ServeOpts {
            batch_max: 4,
            flush: Duration::from_micros(100),
            kv_budget: 1,
            max_steps: 256,
            queue_max: 64,
            deadline: None,
        });
        let err = server.generate(&[1, 2, 3], 8).unwrap_err().to_string();
        assert!(
            err.contains("KV_BUDGET") || err.contains("KV cache"),
            "unexpected error: {err}"
        );
        // single-step generations need no cache and still run
        assert_eq!(server.generate(&[1, 2, 3], 1).unwrap().len(), 4);
        assert!(server.score(vec![1, 2, 3]).is_ok());
    }

    #[test]
    fn protocol_lines_roundtrip() {
        let server = tiny_server(4, Duration::from_micros(100));
        let resp = handle_request_line(&server, "{\"tokens\": [1, 2, 3]}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.req("len").unwrap().as_usize().unwrap(), 3);
        assert!(j.req("next").unwrap().as_usize().unwrap() < 128);
        let resp = handle_request_line(&server, "{\"prompt\": [4, 5], \"steps\": 2}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 2);
        // max_tokens is an alias for steps
        let resp =
            handle_request_line(&server, "{\"prompt\": [4, 5], \"max_tokens\": 3}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 5);
        // an over-cap request errors instead of monopolizing the batcher
        let resp = handle_request_line(
            &server,
            "{\"prompt\": [4, 5], \"steps\": 100000}",
        );
        assert!(
            Json::parse(&resp).unwrap().get("error").is_some(),
            "unbounded generate must error"
        );
        // malformed requests come back as error lines, not panics
        for bad in ["nonsense", "{}", "{\"tokens\": [99999]}", "{\"tokens\": []}"] {
            let resp = handle_request_line(&server, bad);
            assert!(
                Json::parse(&resp).unwrap().get("error").is_some(),
                "{bad} must error"
            );
        }
    }

    #[test]
    fn hostile_payloads_become_clean_protocol_errors() {
        // regression net for the untrusted request path: every payload
        // here once (or plausibly could) hit an unwrap/parse panic —
        // each must come back as an `{"error": ...}` line with the
        // server still alive afterwards
        let server = tiny_server(4, Duration::from_micros(100));
        let deep_nest = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        let hostile: Vec<String> = vec![
            // truncated / malformed escapes and encodings
            "{\"tokens\": [\"\\u00".to_string(),
            "{\"tokens\": \"\\uZZZZ\"}".to_string(),
            "{\"tokens".to_string(),
            // nesting beyond the parser's depth cap
            format!("{{\"tokens\": {deep_nest}}}"),
            // wrong-type fields
            "{\"tokens\": \"abc\"}".to_string(),
            "{\"tokens\": [true, null]}".to_string(),
            "{\"tokens\": [[1]]}".to_string(),
            "{\"prompt\": {\"a\": 1}}".to_string(),
            "{\"prompt\": [1], \"steps\": \"many\"}".to_string(),
            "{\"prompt\": [1], \"steps\": [2]}".to_string(),
            // oversized / non-integral numerics
            "{\"tokens\": [1e300]}".to_string(),
            "{\"tokens\": [2147483648]}".to_string(),
            "{\"tokens\": [-1]}".to_string(),
            "{\"tokens\": [1.5]}".to_string(),
            "{\"prompt\": [1], \"steps\": 1e18}".to_string(),
            "{\"prompt\": [1], \"steps\": -3}".to_string(),
        ];
        for bad in &hostile {
            let resp = handle_request_line(&server, bad);
            let j = Json::parse(&resp).unwrap_or_else(|e| {
                panic!("response to {bad:?} not json: {e} ({resp})")
            });
            assert!(j.get("error").is_some(), "{bad:?} must error, got {resp}");
        }
        // the server survived all of it and still answers real requests
        let resp = handle_request_line(&server, "{\"tokens\": [1, 2]}");
        let j = Json::parse(&resp).unwrap();
        assert!(j.get("error").is_none(), "healthy request failed: {resp}");
        assert_eq!(j.req("len").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn load_test_reports_consistent_counters() {
        let server = tiny_server(4, Duration::from_micros(200));
        let rep = load_test(&server, 3, 4, 7, &LoadMix::default()).unwrap();
        assert_eq!(rep.requests, 12);
        assert_eq!(rep.score_requests, 12);
        assert!(rep.total_tokens >= 12 * 4);
        assert!(rep.throughput_tok_s > 0.0);
        assert!(rep.p50_ms <= rep.p90_ms && rep.p90_ms <= rep.p99_ms);
        assert!(rep.batches >= 3 && rep.batches <= 12);
        assert!(rep.max_batch <= 4);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.tokens, rep.total_tokens);
    }

    #[test]
    fn mixed_load_test_reports_decode_percentiles() {
        let server = tiny_server(4, Duration::from_micros(200));
        let mix = LoadMix {
            generate_frac: 0.5,
            heavy_tail: true,
            max_steps: 12,
        };
        let rep = load_test(&server, 3, 6, 11, &mix).unwrap();
        assert_eq!(rep.requests, 18);
        assert_eq!(rep.score_requests + rep.gen_requests, 18);
        assert!(rep.gen_requests > 0, "mix produced no generations");
        assert!(rep.gen_tokens >= rep.gen_requests);
        assert!(rep.ttft_p50_ms <= rep.ttft_p99_ms);
        assert!(rep.itl_p50_ms <= rep.itl_p99_ms);
        assert!(rep.ttft_p50_ms > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.gen_completed, rep.gen_requests);
    }

    /// A kv_budget sized for exactly one full-window cache, so a second
    /// multi-step generation must wait for the first one's bytes.
    fn one_seq_budget_server(max_steps: usize) -> Server {
        let cfg = ModelConfig::tiny_test();
        let budget = KvCache::bytes_for(&cfg, cfg.ctx);
        tiny_server_opts(ServeOpts {
            batch_max: 4,
            flush: Duration::from_micros(0),
            kv_budget: budget,
            max_steps,
            queue_max: 64,
            deadline: None,
        })
    }

    #[test]
    fn cancelled_generation_frees_kv_budget_for_queued_request() {
        // the disconnect-cancels-sequence path: A holds the entire KV
        // budget on an effectively endless generation; B queues behind
        // it.  Cancelling A must free A's bytes at the next iteration
        // so B admits and completes.
        let server = one_seq_budget_server(1 << 20);
        let a = server
            .try_submit_generate(vec![1, 2, 3, 4], 1 << 20, None)
            .unwrap();
        // wait until A is decoding, so the cancel lands mid-flight
        while server.stats().decode_steps == 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
        let b = server.try_submit_generate(vec![5, 6], 3, None).unwrap();
        a.cancel();
        let out = b.wait().expect("B must admit once A's bytes free");
        assert_eq!(out.tokens.len(), 5);
        assert!(!out.cancelled);
        let err = a.wait().unwrap_err().to_string();
        assert!(err.contains("cancel"), "unexpected A error: {err}");
        let stats = server.stats();
        assert_eq!(stats.gen_cancelled, 1);
        assert_eq!(stats.gen_completed, 1);
    }

    #[test]
    fn dropping_a_gen_handle_cancels_the_sequence() {
        // what the front door does when a client disconnects
        // mid-generation: the handle drops, the sequence dies at the
        // next iteration instead of burning the batcher forever
        let server = one_seq_budget_server(1 << 20);
        let a = server
            .try_submit_generate(vec![1, 2, 3], 1 << 20, None)
            .unwrap();
        while server.stats().decode_steps == 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
        drop(a);
        while server.stats().gen_cancelled == 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
        // the scheduler is idle again and still serves
        assert!(server.score(vec![1, 2]).is_ok());
    }

    #[test]
    fn deadline_mid_flight_returns_cancelled_partial_output() {
        let server = one_seq_budget_server(1 << 20);
        let deadline = Some(Instant::now() + Duration::from_millis(30));
        let h = server
            .try_submit_generate(vec![1, 2, 3, 4], 1 << 20, deadline)
            .unwrap();
        let out = h.wait().expect("expired mid-flight must still respond");
        assert!(out.cancelled, "a ~10s generation must hit a 30ms deadline");
        assert!(out.tokens.len() >= 4, "partial output keeps the prompt");
        assert!(out.tokens.len() < 4 + (1 << 20));
        let line = gen_line(&out);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("cancelled").unwrap(), &Json::Bool(true));
        assert_eq!(server.stats().gen_cancelled, 1);
    }

    #[test]
    fn deadline_expired_while_queued_errors_cleanly() {
        let server = one_seq_budget_server(1 << 20);
        let a = server
            .try_submit_generate(vec![1, 2, 3], 1 << 20, None)
            .unwrap();
        while server.stats().decode_steps == 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
        // B queues behind A's KV hold and its deadline is already gone
        let b = server
            .try_submit_generate(vec![4, 5], 3, Some(Instant::now()))
            .unwrap();
        let err = b.wait().unwrap_err().to_string();
        assert!(err.contains("deadline"), "unexpected B error: {err}");
        a.cancel();
    }

    #[test]
    fn full_queue_sheds_with_retry_after() {
        let cfg = ModelConfig::tiny_test();
        let server = tiny_server_opts(ServeOpts {
            batch_max: 4,
            flush: Duration::from_micros(0),
            kv_budget: KvCache::bytes_for(&cfg, cfg.ctx),
            max_steps: 1 << 20,
            queue_max: 1,
            deadline: None,
        });
        let a = server
            .try_submit_generate(vec![1, 2, 3, 4], 1 << 20, None)
            .unwrap();
        while server.stats().decode_steps == 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
        // B needs KV bytes A holds → parks at the queue head (FIFO)
        let b = server.try_submit_generate(vec![5, 6], 3, None).unwrap();
        // the queue is at its bound: C sheds immediately
        match server.try_submit_score(vec![7, 8], None) {
            Err(SubmitError::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms >= 1);
                let line = overloaded_line(retry_after_ms);
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.req("error").unwrap().as_str().unwrap(), "overloaded");
                assert!(j.req("retry_after_ms").unwrap().as_usize().unwrap() >= 1);
            }
            Ok(_) => panic!("expected overload shed, got an accepted request"),
            Err(e) => panic!("expected overload shed, got {e}"),
        }
        assert_eq!(server.stats().shed, 1);
        a.cancel();
        assert!(b.wait().is_ok(), "queued request must survive the shed");
    }

    #[test]
    fn open_loop_accounts_every_offered_request() {
        let server = tiny_server(4, Duration::from_micros(100));
        let rep =
            load_test_open(&server, 200.0, Duration::from_millis(100), 7).unwrap();
        assert!(rep.offered >= 1);
        assert_eq!(rep.accepted + rep.shed + rep.errors, rep.offered);
        assert_eq!(rep.errors, 0);
        assert!(rep.p50_ms <= rep.p99_ms);
        assert!((rep.shed_frac - rep.shed as f64 / rep.offered as f64).abs() < 1e-12);
    }
}
