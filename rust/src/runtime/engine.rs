//! The PJRT engine: artifact registry + compiled-executable cache +
//! typed wrappers for the two artifact families (ZSIC quantize graphs
//! and picollama forward passes).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::linalg::gemm::Precision;
use crate::util::sync::{classes, TrackedMutex};
use crate::linalg::Mat;
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::quant::zsic::ZsicOut;

pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: TrackedMutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

/// Identifies one exported ZSIC graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ZsicArtifact {
    pub a: usize,
    pub n: usize,
    pub lmmse: bool,
}

impl ZsicArtifact {
    pub fn file_name(&self) -> String {
        let tag = if self.lmmse { "lmmse" } else { "plain" };
        format!("zsic_{tag}_{}x{}.hlo.txt", self.a, self.n)
    }
}

impl Engine {
    /// Create a CPU PJRT client rooted at the artifacts directory.
    pub fn new(artifacts_dir: PathBuf) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            artifacts_dir,
            cache: TrackedMutex::new(&classes::ENGINE_CACHE, HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Default kernel precision for native-path consumers (the
    /// `WATERSIC_PRECISION` engine option; `PipelineOpts::precision`
    /// can override per run).  Derived, not stored — there is exactly
    /// one source of truth.  The PJRT artifacts already run f32
    /// on-device regardless.
    pub fn precision(&self) -> Precision {
        Precision::from_env()
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifacts_dir.join(name)
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Compile (or fetch from cache) an HLO-text artifact.
    fn load(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock();
        if cache.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_path(name);
        if !path.exists() {
            bail!("artifact {} not found (run `make artifacts`)", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let cache = self.cache.lock();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // jax lowers with return_tuple=True → outputs are a tuple
        Ok(lit.to_tuple()?)
    }

    /// Run the ZSIC quantize artifact for a fixed shape.  Inputs are the
    /// L3-prepared (ŷ, L, α); outputs mirror `quant::zsic::zsic`.
    pub fn run_zsic(
        &self,
        art: ZsicArtifact,
        y: &Mat,
        l: &Mat,
        alphas: &[f64],
    ) -> Result<ZsicOut> {
        let (a, n) = (art.a, art.n);
        anyhow::ensure!(y.rows == a && y.cols == n, "shape mismatch");
        let ylit = xla::Literal::vec1(&y.to_f32()).reshape(&[a as i64, n as i64])?;
        let llit = xla::Literal::vec1(&l.to_f32()).reshape(&[n as i64, n as i64])?;
        let alit =
            xla::Literal::vec1(&alphas.iter().map(|&x| x as f32).collect::<Vec<f32>>());
        let outs = self.execute(&art.file_name(), &[ylit, llit, alit])?;
        anyhow::ensure!(outs.len() == 3, "zsic artifact must return 3 outputs");
        let z = outs[0].to_vec::<i32>()?;
        let gammas: Vec<f64> = outs[1]
            .to_vec::<f32>()?
            .into_iter()
            .map(|x| x as f64)
            .collect();
        let resid_f: Vec<f32> = outs[2].to_vec::<f32>()?;
        Ok(ZsicOut {
            z,
            gammas,
            resid: Mat::from_f32(a, n, &resid_f),
        })
    }

    /// Run the picollama forward artifact: weights (in manifest
    /// `param_order`) + a (B × ctx) token batch → (B·ctx × V) logits.
    pub fn run_forward(
        &self,
        cfg: &ModelConfig,
        weights: &Weights,
        tokens: &[i32],
        batch: usize,
    ) -> Result<Mat> {
        anyhow::ensure!(
            tokens.len() == batch * cfg.ctx,
            "token batch must be {}x{}",
            batch,
            cfg.ctx
        );
        let name = format!("forward_{}.hlo.txt", cfg.name);
        let mut args: Vec<xla::Literal> = Vec::new();
        for (pname, buf) in cfg
            .param_order
            .iter()
            .zip(weights.flatten_f32(&cfg.param_order))
        {
            let lit = xla::Literal::vec1(&buf);
            let lit = if let Some(m) = weights.mats.get(pname) {
                lit.reshape(&[m.rows as i64, m.cols as i64])?
            } else {
                lit
            };
            args.push(lit);
        }
        args.push(
            xla::Literal::vec1(tokens).reshape(&[batch as i64, cfg.ctx as i64])?,
        );
        let outs = self.execute(&name, &args)?;
        let logits: Vec<f32> = outs[0].to_vec::<f32>()?;
        anyhow::ensure!(
            logits.len() == batch * cfg.ctx * cfg.vocab,
            "bad logits size"
        );
        Ok(Mat::from_f32(batch * cfg.ctx, cfg.vocab, &logits))
    }

    /// The ZSIC executor closure used by the coordinator: routes to the
    /// artifact when one exists for the shape, else falls back to the
    /// native implementation.  Returns whether the artifact path was hit.
    pub fn zsic_exec(
        &self,
        y: &Mat,
        l: &Mat,
        alphas: &[f64],
        lmmse: bool,
    ) -> (ZsicOut, bool) {
        let art = ZsicArtifact {
            a: y.rows,
            n: y.cols,
            lmmse,
        };
        if self.has_artifact(&art.file_name()) {
            match self.run_zsic(art, y, l, alphas) {
                Ok(out) => return (out, true),
                Err(e) => {
                    log::warn!("zsic artifact failed ({e:#}); falling back to native");
                }
            }
        }
        (crate::quant::zsic::zsic(y, l, alphas, lmmse, None), false)
    }
}

// Integration-level tests that need built artifacts live in
// rust/tests/runtime_integration.rs; here only pure helpers are tested.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        let a = ZsicArtifact {
            a: 512,
            n: 128,
            lmmse: true,
        };
        assert_eq!(a.file_name(), "zsic_lmmse_512x128.hlo.txt");
        let b = ZsicArtifact {
            a: 64,
            n: 64,
            lmmse: false,
        };
        assert_eq!(b.file_name(), "zsic_plain_64x64.hlo.txt");
    }
}
