//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate.  This is the production execution path — python
//! never runs here.  Executables are compiled once and cached.

pub mod engine;

pub use engine::{Engine, ZsicArtifact};
// The native-path kernel options are part of the engine surface: the
// coordinator reads them from here rather than reaching into linalg.
pub use crate::linalg::gemm::{simd_backend, Precision, SimdBackend};
