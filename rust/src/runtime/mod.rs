//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate.  This is the production execution path — python
//! never runs here.  Executables are compiled once and cached.

pub mod engine;
pub mod reactor;
pub mod server;

pub use engine::{Engine, ZsicArtifact};
pub use reactor::ReactorOpts;
pub use server::{GenOut, LoadMix, LoadReport, Server, ServeOpts, ServeStats, SubmitError};
// The native-path kernel options are part of the engine surface: the
// coordinator reads them from here rather than reaching into linalg.
pub use crate::linalg::gemm::{simd_backend, Precision, SimdBackend};

/// The `WATERSIC_PREPARE_LOOKAHEAD` engine option: how many prepared
/// layer front-ends (stats + [`crate::quant::PreparedLayer`] pairs) the
/// coordinator's streaming prepare may hold alive at once — the one
/// the budget loop is draining plus the buffered lookahead built ahead
/// of it.  A memory bound, not a build concurrency (builds run one at
/// a time, each internally pool-parallel).  Default 2 (prepare one
/// ahead), minimum 1 (fully serial, lowest memory).
/// `PipelineOpts::prepare_lookahead` can override per run.
pub fn prepare_lookahead_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_PREPARE_LOOKAHEAD")
        .map(|n| n.max(1))
        .unwrap_or(2)
}
