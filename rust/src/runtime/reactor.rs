//! Event-driven TCP front door for the serving engine.
//!
//! One reactor thread multiplexes every connection through a readiness
//! poller (epoll on Linux, kqueue on macOS, via the vendored `polling`
//! shim) instead of the old thread-per-connection model: non-blocking
//! accept behind a hard connection cap, per-connection read/write state
//! machines with idle and write-stall timeouts, and request lines
//! submitted to the [`Server`] scheduler through its *non-blocking*
//! typed admission path — a slow generation never parks an OS thread,
//! and an admission-queue overflow comes back to the client immediately
//! as `{"error":"overloaded","retry_after_ms":N}`.
//!
//! Responses drain in request order per connection (head-of-line by
//! design: the protocol has no request ids), via [`ScoreHandle::
//! try_wait`]/[`GenHandle::try_wait`] polls each tick.  Dropping a
//! connection drops its handles, which cancels any in-flight
//! generation at the scheduler's next iteration and frees its KV bytes
//! (see [`GenHandle`]).
//!
//! On platforms with no readiness backend, [`serve`] falls back to
//! [`serve_threaded`]: the same protocol, one thread per connection,
//! still behind the connection cap and with `set_read_timeout`/
//! `set_write_timeout` bounding idle and stalled peers.
//!
//! Shutdown (the `stop` flag, wired to SIGINT by `main.rs`) drains
//! rather than aborts: the listener stops accepting, queued and
//! in-flight requests finish (or get deadline-cancelled by the
//! scheduler), the responses flush, and then the loop exits.
//!
//! Fault-injection sites (`util::fault`, `fault-inject` builds only):
//! `accept` (drop a fresh connection), `read` (partial/slow reads),
//! `conn` (kill a connection on a complete request line), `write`
//! (stall before flushing).  The `sched` site lives in the scheduler.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::runtime::server::{
    self as serve, error_line, overloaded_line, GenHandle, ScoreHandle, Server, Submitted,
};
use crate::util::fault::{self, Fault};

/// The `WATERSIC_SERVE_MAX_CONNS` engine option: hard cap on concurrent
/// front-door connections.  A connection beyond the cap gets one
/// best-effort `overloaded` line and is closed.  Default 1024, min 1.
pub fn serve_max_conns_from_env() -> usize {
    crate::util::env::parsed::<usize>("WATERSIC_SERVE_MAX_CONNS")
        .map(|n| n.max(1))
        .unwrap_or(1024)
}

/// The `WATERSIC_SERVE_IDLE_MS` engine option: per-connection idle
/// timeout — a connection with no request bytes and nothing in flight
/// for this long is closed (slow-loris bound).  Default 60s, min 1ms.
pub fn serve_idle_ms_from_env() -> Duration {
    Duration::from_millis(
        crate::util::env::parsed::<u64>("WATERSIC_SERVE_IDLE_MS")
            .map(|n| n.max(1))
            .unwrap_or(60_000),
    )
}

/// The `WATERSIC_SERVE_WRITE_MS` engine option: per-connection
/// write-stall timeout — a peer that stops draining its responses for
/// this long is dropped (its buffered bytes can't grow unboundedly).
/// Default 10s, min 1ms.
pub fn serve_write_ms_from_env() -> Duration {
    Duration::from_millis(
        crate::util::env::parsed::<u64>("WATERSIC_SERVE_WRITE_MS")
            .map(|n| n.max(1))
            .unwrap_or(10_000),
    )
}

/// A request line longer than this is rejected and the connection
/// closed — an unbounded line buffer would let one client grow memory
/// until the server OOMs.
pub const MAX_REQUEST_LINE: usize = 1 << 20;

/// Front-door limits (the scheduler's own limits live in
/// [`serve::ServeOpts`]).
#[derive(Clone, Copy, Debug)]
pub struct ReactorOpts {
    pub max_conns: usize,
    pub idle: Duration,
    pub write_stall: Duration,
}

impl Default for ReactorOpts {
    fn default() -> ReactorOpts {
        ReactorOpts {
            max_conns: serve_max_conns_from_env(),
            idle: serve_idle_ms_from_env(),
            write_stall: serve_write_ms_from_env(),
        }
    }
}

/// Serve the line-JSON protocol on `listener` until `stop` is set:
/// the event-driven reactor where a readiness backend exists, else the
/// threaded fallback.  Returns once drained.
pub fn serve(
    server: &Arc<Server>,
    listener: &TcpListener,
    opts: &ReactorOpts,
    stop: &AtomicBool,
) -> Result<()> {
    match polling::Poller::new() {
        Ok(poller) => serve_reactor_on(server, listener, &poller, opts, stop),
        Err(e) if e.kind() == ErrorKind::Unsupported => {
            log::warn!("no readiness backend ({e}); using thread-per-connection");
            serve_threaded(server, listener, opts, stop)
        }
        Err(e) => Err(e).context("creating readiness poller"),
    }
}

/// poller key of the listening socket (connections start at 1)
const KEY_LISTENER: usize = 0;

/// One response slot, kept in submit order per connection.
enum OutItem {
    /// answered at submit time (errors, sheds, `steps: 0` echo)
    Now(String),
    Score(ScoreHandle),
    Gen(GenHandle),
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// accumulated request bytes (may end mid-line)
    rbuf: Vec<u8>,
    /// responses pending or in flight, in request order
    out: VecDeque<OutItem>,
    /// serialized response bytes not yet written (`wpos` = progress)
    wbuf: Vec<u8>,
    wpos: usize,
    last_activity: Instant,
    /// set while a write has made no progress (stall timeout base)
    stalled_since: Option<Instant>,
    /// flush what's pending, accept no new requests, then close
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            out: VecDeque::new(),
            wbuf: Vec::new(),
            wpos: 0,
            last_activity: Instant::now(),
            stalled_since: None,
            closing: false,
        }
    }

    fn done(&self) -> bool {
        self.out.is_empty() && self.wbuf.is_empty()
    }
}

/// The event-driven front door (public entry; creates its own poller).
pub fn serve_reactor(
    server: &Arc<Server>,
    listener: &TcpListener,
    opts: &ReactorOpts,
    stop: &AtomicBool,
) -> Result<()> {
    let poller = polling::Poller::new().context("creating readiness poller")?;
    serve_reactor_on(server, listener, &poller, opts, stop)
}

fn serve_reactor_on(
    server: &Server,
    listener: &TcpListener,
    poller: &polling::Poller,
    opts: &ReactorOpts,
    stop: &AtomicBool,
) -> Result<()> {
    use std::os::fd::AsRawFd;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    poller
        .add(listener.as_raw_fd(), polling::Event::readable(KEY_LISTENER))
        .context("registering listener")?;
    let mut conns: HashMap<usize, Conn> = HashMap::new();
    let mut next_key = KEY_LISTENER + 1;
    let mut events: Vec<polling::Event> = Vec::new();
    let mut draining = false;
    loop {
        if stop.load(Ordering::Relaxed) && !draining {
            draining = true;
            let _ = poller.delete(listener.as_raw_fd());
            for c in conns.values_mut() {
                c.closing = true;
            }
        }
        if draining && conns.is_empty() {
            return Ok(());
        }
        // short tick while responses are pending (try_wait polls need
        // it); long tick when purely waiting on sockets
        let busy = conns.values().any(|c| !c.done());
        let tick = if busy || draining {
            Duration::from_millis(2)
        } else {
            Duration::from_millis(200)
        };
        events.clear();
        poller
            .wait(&mut events, Some(tick))
            .context("polling for readiness")?;
        let mut dead: Vec<usize> = Vec::new();
        for ev in &events {
            if ev.key == KEY_LISTENER {
                accept_ready(server, listener, poller, opts, &mut conns, &mut next_key);
            } else if ev.readable {
                if let Some(c) = conns.get_mut(&ev.key) {
                    if !read_ready(server, c) {
                        dead.push(ev.key);
                    }
                }
            }
            // writable readiness needs no handler: every pending wbuf
            // is re-flushed on the (short) tick below
        }
        let now = Instant::now();
        for (&key, c) in conns.iter_mut() {
            drain_out(c);
            if !flush(c, opts.write_stall) {
                dead.push(key);
                continue;
            }
            let idle_out = now.duration_since(c.last_activity) > opts.idle;
            if c.done() && (c.closing || idle_out) {
                dead.push(key);
            }
        }
        for key in dead {
            if let Some(c) = conns.remove(&key) {
                let _ = poller.delete(c.stream.as_raw_fd());
                // dropping the Conn drops its handles: any in-flight
                // generation is cancelled and its KV bytes freed
            }
        }
    }
}

/// Accept until `WouldBlock`, applying the connection cap.
fn accept_ready(
    server: &Server,
    listener: &TcpListener,
    poller: &polling::Poller,
    opts: &ReactorOpts,
    conns: &mut HashMap<usize, Conn>,
    next_key: &mut usize,
) {
    use std::os::fd::AsRawFd;
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                log::warn!("[serve] accept failed: {e}");
                return;
            }
        };
        if let Some(Fault::Disconnect) = fault::check("accept") {
            continue; // injected: drop the fresh connection on the floor
        }
        if conns.len() >= opts.max_conns {
            shed_connection(server, stream);
            continue;
        }
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        let key = *next_key;
        *next_key += 1;
        if poller
            .add(stream.as_raw_fd(), polling::Event::readable(key))
            .is_err()
        {
            continue;
        }
        conns.insert(key, Conn::new(stream));
    }
}

/// One best-effort `overloaded` line on a blocking socket, then close.
// lint:allow(reactor-blocking) — deliberate bounded blocking write (250 ms
// timeout caps it) so the shed message actually reaches the peer
fn shed_connection(server: &Server, mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let msg = overloaded_line(server.retry_after_hint_ms());
    let _ = stream
        .write_all(msg.as_bytes())
        .and_then(|()| stream.write_all(b"\n"));
}

/// Pull available bytes and submit any completed lines.  `false` means
/// the connection is gone (EOF, error, or injected disconnect).
fn read_ready(server: &Server, c: &mut Conn) -> bool {
    let mut per_pass = usize::MAX;
    match fault::check("read") {
        Some(Fault::Disconnect) => return false,
        // lint:allow(reactor-blocking) — injected fault: the delay is the point
        Some(Fault::SlowRead { ms }) => std::thread::sleep(Duration::from_millis(ms)),
        Some(Fault::PartialRead) => per_pass = 1,
        _ => {}
    }
    let mut buf = [0u8; 4096];
    loop {
        let want = per_pass.min(buf.len());
        match c.stream.read(&mut buf[..want]) {
            Ok(0) => return false, // clean EOF
            Ok(n) => {
                c.last_activity = Instant::now();
                c.rbuf.extend_from_slice(&buf[..n]);
                if !consume_lines(server, c) {
                    return false;
                }
                if n < want || per_pass == 1 {
                    return true;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Parse and submit every complete line in `rbuf`.  `false` means an
/// injected mid-request disconnect.
fn consume_lines(server: &Server, c: &mut Conn) -> bool {
    while let Some(nl) = c.rbuf.iter().position(|&b| b == b'\n') {
        let line: Vec<u8> = c.rbuf.drain(..=nl).collect();
        if c.closing {
            continue; // draining: flush what's in flight, take no more
        }
        if let Some(Fault::Disconnect) = fault::check("conn") {
            return false;
        }
        let Ok(text) = std::str::from_utf8(&line) else {
            c.out.push_back(OutItem::Now(error_line("request not utf-8")));
            c.closing = true;
            continue;
        };
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        let item = match serve::submit_request_line(server, text) {
            Submitted::Ready(s) => OutItem::Now(s),
            Submitted::Score(h) => OutItem::Score(h),
            Submitted::Gen(h) => OutItem::Gen(h),
        };
        c.out.push_back(item);
    }
    if c.rbuf.len() > MAX_REQUEST_LINE {
        if !c.closing {
            c.out
                .push_back(OutItem::Now(error_line("request line too long")));
            c.closing = true;
        }
        // keep draining (harmlessly) so the peer's writes don't wedge
        c.rbuf.clear();
    }
    true
}

/// Move completed responses (in request order) into the write buffer.
fn drain_out(c: &mut Conn) {
    loop {
        let line = match c.out.front() {
            None => return,
            Some(OutItem::Now(s)) => s.clone(),
            Some(OutItem::Score(h)) => match h.try_wait() {
                None => return, // head still in flight: keep order
                Some(Ok(o)) => serve::score_line(&o),
                Some(Err(e)) => error_line(&format!("{e:#}")),
            },
            Some(OutItem::Gen(h)) => match h.try_wait() {
                None => return,
                Some(Ok(o)) => serve::gen_line(&o),
                Some(Err(e)) => error_line(&format!("{e:#}")),
            },
        };
        c.out.pop_front();
        c.wbuf.extend_from_slice(line.as_bytes());
        c.wbuf.push(b'\n');
    }
}

/// Write as much of `wbuf` as the socket takes.  `false` means the
/// connection is dead (error, or stalled past the timeout).
fn flush(c: &mut Conn, write_stall: Duration) -> bool {
    if c.wpos >= c.wbuf.len() {
        c.wbuf.clear();
        c.wpos = 0;
        c.stalled_since = None;
        return true;
    }
    if let Some(Fault::WriteStall { ms }) = fault::check("write") {
        // lint:allow(reactor-blocking) — injected fault: the stall is the point
        std::thread::sleep(Duration::from_millis(ms));
    }
    loop {
        match c.stream.write(&c.wbuf[c.wpos..]) {
            Ok(0) => return false,
            Ok(n) => {
                c.wpos += n;
                c.stalled_since = None;
                c.last_activity = Instant::now();
                if c.wpos >= c.wbuf.len() {
                    c.wbuf.clear();
                    c.wpos = 0;
                    return true;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                let since = *c.stalled_since.get_or_insert_with(Instant::now);
                return since.elapsed() <= write_stall;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

// ---------------------------------------------------------------------
// threaded fallback

/// Thread-per-connection fallback front door: same protocol and the
/// same connection cap, with `set_read_timeout` bounding idle peers
/// and `set_write_timeout` bounding stalled ones.  Used when no
/// readiness backend exists (and directly testable on any platform).
// lint:allow(reactor-blocking) — thread-per-connection fallback: each
// connection owns a thread, so blocking socket I/O is the design
pub fn serve_threaded(
    server: &Arc<Server>,
    listener: &TcpListener,
    opts: &ReactorOpts,
    stop: &AtomicBool,
) -> Result<()> {
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let active = Arc::new(AtomicUsize::new(0));
    while !stop.load(Ordering::Relaxed) {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                log::warn!("[serve] accept failed: {e}");
                continue;
            }
        };
        if let Some(Fault::Disconnect) = fault::check("accept") {
            continue;
        }
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        if active.load(Ordering::Relaxed) >= opts.max_conns {
            shed_connection(server, stream);
            continue;
        }
        active.fetch_add(1, Ordering::Relaxed);
        let srv = server.clone();
        let count = active.clone();
        let (idle, write_stall) = (opts.idle, opts.write_stall);
        let spawned = std::thread::Builder::new()
            .name("watersic-serve-conn".to_string())
            .spawn(move || {
                handle_connection(&srv, stream, idle, write_stall);
                count.fetch_sub(1, Ordering::Relaxed);
            });
        if spawned.is_err() {
            active.fetch_sub(1, Ordering::Relaxed);
        }
    }
    // drain: in-flight handlers finish their current request (the
    // socket timeouts bound how long an idle peer can hold one)
    while active.load(Ordering::Relaxed) > 0 {
        std::thread::sleep(Duration::from_millis(10));
    }
    Ok(())
}

/// Blocking per-connection loop of the threaded fallback.
// lint:allow(reactor-blocking) — threaded fallback: this loop runs on a
// dedicated per-connection thread, never on the event loop
fn handle_connection(
    server: &Server,
    stream: TcpStream,
    idle: Duration,
    write_stall: Duration,
) {
    use std::io::BufRead;
    if stream.set_read_timeout(Some(idle)).is_err()
        || stream.set_write_timeout(Some(write_stall)).is_err()
    {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("[serve] connection clone failed: {e}");
            return;
        }
    };
    let mut reader = std::io::BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        match fault::check("read") {
            Some(Fault::Disconnect) => return,
            Some(Fault::SlowRead { ms }) => std::thread::sleep(Duration::from_millis(ms)),
            // a buffered blocking reader has no partial-read notion
            _ => {}
        }
        buf.clear();
        // re-armed per line: bounds each request, not the session; a
        // timeout here is the idle bound kicking in
        let n = match (&mut reader)
            .take(MAX_REQUEST_LINE as u64)
            .read_until(b'\n', &mut buf)
        {
            Ok(0) => return, // clean EOF
            Ok(n) => n,
            Err(_) => return,
        };
        if n >= MAX_REQUEST_LINE && buf.last() != Some(&b'\n') {
            let _ = writer.write_all(b"{\"error\": \"request line too long\"}\n");
            return;
        }
        let Ok(line) = std::str::from_utf8(&buf) else {
            let _ = writer.write_all(b"{\"error\": \"request not utf-8\"}\n");
            return;
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Some(Fault::Disconnect) = fault::check("conn") {
            return;
        }
        let resp = serve::handle_request_line(server, line.trim_end());
        if let Some(Fault::WriteStall { ms }) = fault::check("write") {
            std::thread::sleep(Duration::from_millis(ms));
        }
        if writer
            .write_all(resp.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .is_err()
        {
            return;
        }
    }
}
