//! The end-to-end quantization pipeline (Algorithm 3 at model scope):
//! layers are processed sequentially front-to-back; for each layer the
//! student (partially quantized model) is re-run over the calibration
//! set to refresh the drift statistics, each matrix is quantized at the
//! rate assigned by the running global budget, and the student weights
//! are updated in place so later layers see the accumulated error.
//!
//! The expensive per-matrix front-end (drift-stat assembly + the
//! WaterSIC [`PreparedLayer`] build) is rate-independent, so it is
//! **streamed**: a producer thread builds front-ends ahead of the
//! inherently sequential budget loop — one matrix at a time, each
//! build internally parallel over the worker pool — with the bounded
//! window W ([`PipelineOpts::prepare_lookahead`],
//! `WATERSIC_PREPARE_LOOKAHEAD`, default 2) capping how many prepared
//! front-ends are alive at once.  W is a *buffer* bound, not a build
//! concurrency: W = 2 already overlaps preparing matrix k+1 with
//! consuming matrix k, and larger windows only let the producer run
//! further ahead.  Assigned rates and every output bit are identical
//! to the strictly in-order pipeline, at a bounded fraction of the
//! all-at-once transient footprint.

use std::collections::BTreeMap;
use std::sync::mpsc;

use anyhow::{Context, Result};

use crate::calib::corpus::Corpus;
use crate::calib::drift::{panel_rel_mse, student_panels, CalibSet, StatsOpts};
use crate::linalg::Mat;
use crate::model::transformer::{attention_block_output, input_group};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::quant::gptq::gptq_at_rate;
use crate::quant::mixing::{mix_attention, mix_drift, optimize_mixing};
use crate::quant::rate_control::RateBudget;
use crate::quant::rtn::{rtn_absmax, rtn_grid_at_rate};
use crate::quant::watersic::{
    layer_seed_from_name, prepare_at_rate, watersic_at_rate, watersic_at_rate_prepared,
    PreparedLayer,
};
use crate::quant::{LayerQuant, LayerStats, QuantOpts};
use crate::runtime::{Engine, Precision};
use crate::util::sync::{classes, TrackedCondvar, TrackedMutex};

/// The two front-ends a rate-targeted WaterSIC matrix needs: the full
/// system and (when subsampling is in effect) the secant's row
/// subsample, sharing one `PreparedStats`.
type PreparedPair = (PreparedLayer, Option<PreparedLayer>);

/// Which algorithm the pipeline runs — the rows of Tables 1/2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// per-row absmax RTN at an integer bit-width (log-cardinality rate)
    Rtn { bits: u32 },
    /// ε-grid RTN + entropy coding at the target rate
    HuffRtn,
    /// GPTQ with maxq clamp (log-cardinality rate)
    Gptq { maxq: i32 },
    /// Huffman-GPTQ (HPTQ): entropy-coded GPTQ at the target rate
    HuffGptq,
    /// full WaterSIC
    WaterSic,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Rtn { .. } => "RTN",
            Algo::HuffRtn => "Huffman-RTN",
            Algo::Gptq { .. } => "GPTQ",
            Algo::HuffGptq => "Huffman-GPTQ",
            Algo::WaterSic => "WaterSIC",
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub algo: Algo,
    /// target average rate (bits/weight) over the quantizable params
    pub target_rate: f64,
    pub calib_windows: usize,
    pub calib_batch: usize,
    pub seed: u64,
    /// §4 corrections (WaterSIC only)
    pub drift: bool,
    pub residual: bool,
    pub attn_weighted: bool,
    pub mixing: bool,
    pub mixing_iters: usize,
    pub quant: QuantOpts,
    /// rows used during secant rate search
    pub subsample_rows: usize,
    /// how many prepared layer front-ends the streaming prepare may
    /// hold alive at once (the one being drained + the buffered
    /// lookahead); min 1 = fully serial.  A memory bound, not a build
    /// concurrency — builds run one at a time (each pool-parallel
    /// internally), so values above 2 only deepen the buffer.
    /// Defaults to the `WATERSIC_PREPARE_LOOKAHEAD` engine option (2).
    pub prepare_lookahead: usize,
    /// kernel precision for calibration forwards and covariance
    /// streaming (the quantizer core stays f64 regardless); defaults
    /// to the `WATERSIC_PRECISION` engine option
    pub precision: Precision,
    /// route fixed shapes through the PJRT ZSIC artifact
    pub use_engine: bool,
    /// run WaterSIC-FT afterwards
    pub finetune: Option<crate::ft::FtOpts>,
}

impl PipelineOpts {
    pub fn watersic(rate: f64) -> Self {
        PipelineOpts {
            algo: Algo::WaterSic,
            target_rate: rate,
            calib_windows: 12,
            calib_batch: 4,
            seed: 17,
            drift: true,
            residual: true,
            attn_weighted: true,
            mixing: false, // costly; enabled explicitly by experiments
            mixing_iters: 5,
            quant: QuantOpts::default(),
            subsample_rows: 64,
            prepare_lookahead: crate::runtime::prepare_lookahead_from_env(),
            precision: Precision::from_env(),
            use_engine: true,
            finetune: None,
        }
    }

    pub fn baseline(algo: Algo, rate: f64) -> Self {
        PipelineOpts {
            algo,
            drift: matches!(algo, Algo::HuffGptq), // HPTQ uses X̂ stats
            residual: false,
            attn_weighted: false,
            mixing: false,
            quant: QuantOpts::gptq(),
            ..PipelineOpts::watersic(rate)
        }
    }
}

/// Per-matrix outcome.
#[derive(Clone, Debug)]
pub struct MatrixReport {
    pub name: String,
    pub assigned_rate: f64,
    pub entropy_bits: f64,
    pub rate_bits: f64,
    pub rel_mse_weights: f64,
    pub dead_cols: usize,
    pub via_artifact: bool,
}

#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub matrices: Vec<MatrixReport>,
    /// relative MSE of the student input panel at each group, after the
    /// full pipeline (ablation figures)
    pub input_rel_mse: Vec<(String, f64)>,
    /// optimal mixing coefficients per layer (ε_qr, ε_aw)
    pub mixing: Vec<(usize, f64, f64)>,
    pub avg_rate: f64,
    pub ft_loss_trace: Vec<f64>,
    pub wall_secs: f64,
    /// high-water mark of simultaneously-alive prepared front-ends in
    /// the streaming prepare (≤ `PipelineOpts::prepare_lookahead`; 0
    /// when the streaming path did not run)
    pub prepare_peak_pairs: usize,
}

pub struct QuantizedModel {
    pub student: Weights,
    pub quants: BTreeMap<String, LayerQuant>,
    pub report: PipelineReport,
}

/// One matrix through the configured algorithm.  For WaterSIC the
/// coordinator may hand in `prepared` front-ends (streamed over the
/// worker pool — see `quantize_model`); without them the rate search
/// prepares its own, salting the subsample RNG with `layer_seed`.
/// `stats` is required by every path except prepared WaterSIC (the
/// pair already holds everything the quantizer reads — the streaming
/// consumer exploits this to drop the covariances right after prepare).
fn quantize_matrix(
    w: &Mat,
    stats: Option<&LayerStats>,
    rate: f64,
    opts: &PipelineOpts,
    engine: Option<&Engine>,
    prepared: Option<PreparedPair>,
    layer_seed: u64,
) -> Result<(LayerQuant, bool)> {
    let via_artifact;
    let need_stats = || stats.context("this quantization path needs layer stats");
    match opts.algo {
        Algo::Rtn { bits } => Ok((rtn_absmax(w, bits), false)),
        Algo::HuffRtn => Ok((rtn_grid_at_rate(w, rate), false)),
        Algo::Gptq { maxq } => {
            // classical grid: spacing from the weight absmax
            let absmax = w.data.iter().fold(0.0f64, |m, x| m.max(x.abs()));
            let alpha = absmax / maxq as f64;
            Ok((
                crate::quant::gptq::gptq_layer_stats(
                    w,
                    need_stats()?,
                    alpha,
                    false,
                    Some(maxq),
                    0.1,
                )?,
                false,
            ))
        }
        Algo::HuffGptq => Ok((gptq_at_rate(w, need_stats()?, rate, false, 0.1)?, false)),
        Algo::WaterSic => {
            let exec = engine.filter(|_| opts.use_engine).map(|e| {
                move |y: &Mat, l: &Mat, alphas: &[f64], lmmse: bool| {
                    let (out, hit) = e.zsic_exec(y, l, alphas, lmmse);
                    if hit {
                        // soft signal: record artifact usage via thread-local
                        ARTIFACT_HIT.with(|f| f.set(true));
                    }
                    out
                }
            });
            ARTIFACT_HIT.with(|f| f.set(false));
            let q = match (&exec, prepared) {
                (Some(f), Some((full, sub))) => watersic_at_rate_prepared(
                    sub.as_ref().unwrap_or(&full),
                    &full,
                    rate,
                    &opts.quant,
                    Some(f),
                ),
                (None, Some((full, sub))) => watersic_at_rate_prepared(
                    sub.as_ref().unwrap_or(&full),
                    &full,
                    rate,
                    &opts.quant,
                    None,
                ),
                (Some(f), None) => watersic_at_rate(
                    w,
                    need_stats()?,
                    rate,
                    &opts.quant,
                    Some(f),
                    opts.subsample_rows,
                    layer_seed,
                )?,
                (None, None) => watersic_at_rate(
                    w,
                    need_stats()?,
                    rate,
                    &opts.quant,
                    None,
                    opts.subsample_rows,
                    layer_seed,
                )?,
            };
            via_artifact = ARTIFACT_HIT.with(|f| f.get());
            Ok((q, via_artifact))
        }
    }
}

thread_local! {
    static ARTIFACT_HIT: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Counting semaphore bounding how many prepared layer front-ends
/// (drift stats + [`PreparedPair`]) are alive at once in the streaming
/// prepare: the producer acquires a slot *before* it starts building a
/// pair and the budget loop releases the slot only after the pair has
/// been consumed and dropped — so at any instant at most `window`
/// pairs exist, including the one being drained.  Tracks a high-water
/// mark for the report/bench telemetry.
struct PrepareWindow {
    state: TrackedMutex<WindowState>,
    cv: TrackedCondvar,
}

struct WindowState {
    available: usize,
    in_use: usize,
    peak: usize,
    closed: bool,
}

impl PrepareWindow {
    fn new(window: usize) -> PrepareWindow {
        PrepareWindow {
            state: TrackedMutex::new(
                &classes::PIPELINE_WINDOW,
                WindowState {
                    available: window.max(1),
                    in_use: 0,
                    peak: 0,
                    closed: false,
                },
            ),
            cv: TrackedCondvar::new(),
        }
    }

    /// Block until a slot frees up; `false` once the window is closed
    /// (the consumer bailed out — stop producing).
    fn acquire(&self) -> bool {
        let mut st = self.state.lock();
        while st.available == 0 && !st.closed {
            st = self.cv.wait(st);
        }
        if st.closed {
            return false;
        }
        st.available -= 1;
        st.in_use += 1;
        st.peak = st.peak.max(st.in_use);
        true
    }

    fn release(&self) {
        let mut st = self.state.lock();
        st.available += 1;
        st.in_use -= 1;
        self.cv.notify_all();
    }

    /// Wake and dismiss a producer blocked in `acquire` — called via
    /// [`CloseOnDrop`] on every consumer exit (return, error, panic);
    /// without it the scoped join would deadlock.
    fn close(&self) {
        self.state.lock().closed = true;
        self.cv.notify_all();
    }

    fn peak(&self) -> usize {
        self.state.lock().peak
    }
}

/// Closes the window when dropped — `thread::scope` joins the producer
/// before propagating a consumer panic, so without this a panicking
/// budget loop would leave the producer parked in `acquire()` forever.
struct CloseOnDrop<'a>(&'a PrepareWindow);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Drain one matrix through the budgeted quantization step: assign a
/// rate from the running budget, quantize, charge the *achieved* bits
/// back, record the report row, and install the quantized weights in
/// the student.  Inherently sequential — each matrix's achieved bits
/// feed the next assignment, which is exactly why only the prepare is
/// streamed.
#[allow(clippy::too_many_arguments)]
fn consume_matrix(
    name: &str,
    w: &Mat,
    sigma_x: &Mat,
    stats: Option<&LayerStats>,
    prep: Option<PreparedPair>,
    opts: &PipelineOpts,
    engine: Option<&Engine>,
    budget: &mut RateBudget,
    report: &mut PipelineReport,
    student: &mut Weights,
    quants: &mut BTreeMap<String, LayerQuant>,
) -> Result<()> {
    let params = w.rows * w.cols;
    let rate = budget.assign(params);
    let (q, via_artifact) =
        quantize_matrix(w, stats, rate, opts, engine, prep, layer_seed_from_name(name))?;
    // entropy-coded methods report/charge entropy (paper's
    // convention); log-cardinality methods charge their width
    let charged = match opts.algo {
        Algo::Rtn { .. } | Algo::Gptq { .. } => q.rate_bits,
        _ => q.entropy_bits,
    };
    budget.charge(params, charged);
    let w_hat = q.dequant();
    report.matrices.push(MatrixReport {
        name: name.to_string(),
        assigned_rate: rate,
        entropy_bits: q.entropy_bits,
        rate_bits: q.rate_bits,
        rel_mse_weights: crate::quant::relative_distortion(w, &w_hat, sigma_x),
        dead_cols: q.dead_cols.len(),
        via_artifact,
    });
    student.set(name, w_hat);
    quants.insert(name.to_string(), q);
    Ok(())
}

/// Run the full pipeline.
pub fn quantize_model(
    cfg: &ModelConfig,
    teacher: &Weights,
    corpus: &Corpus,
    opts: &PipelineOpts,
    engine: Option<&Engine>,
) -> Result<QuantizedModel> {
    let t0 = std::time::Instant::now();
    let windows = corpus.calib_windows(opts.calib_windows, cfg.ctx, opts.seed);
    let batches: Vec<Vec<i32>> =
        crate::calib::corpus::batch_windows(&windows, opts.calib_batch)
            .into_iter()
            .map(|(t, _)| t)
            .collect();
    // the pipeline opts own the native-path kernel precision
    // (Engine::precision reflects the same WATERSIC_PRECISION default
    // for the runtime's own info surfaces)
    let cs = CalibSet::build_prec(cfg, teacher, batches, opts.calib_batch, opts.precision);

    let mut student = teacher.clone();
    let mut quants: BTreeMap<String, LayerQuant> = BTreeMap::new();
    let mut report = PipelineReport::default();
    let mut budget = RateBudget::new(opts.target_rate, cfg.quantizable_params());

    let stats_opts = StatsOpts {
        drift: opts.drift,
        residual: opts.residual,
        attn_weighted: opts.attn_weighted,
    };

    for li in 0..cfg.n_layers {
        let p = format!("layers.{li}.");
        // refresh student statistics once per layer
        let scaps = cs.student_pass(cfg, &student);

        // ---- joint QKV (with optional adaptive mixing)
        let qkv: Vec<String> = ["wq", "wk", "wv"]
            .iter()
            .map(|w| format!("{p}attn.{w}"))
            .collect();
        let (mut eps_qr, mut eps_aw) = (0.0, 0.0);
        if opts.mixing && opts.algo == Algo::WaterSic {
            let group = format!("{p}attn.qkv");
            let t_panel = &cs.teacher_caps[0].inputs[&group];
            let s_panel = &scaps[0].inputs[&group];
            // teacher attention output (reference for eq. 60)
            let t_out = attention_block_output(
                cfg,
                teacher.get(&qkv[0]),
                teacher.get(&qkv[1]),
                teacher.get(&qkv[2]),
                t_panel,
                opts.calib_batch,
                cfg.ctx,
            );
            let t_norm: f64 = t_out.data.iter().map(|x| x * x).sum();
            let rate_now = budget.assign(0);
            let objective = |eqr: f64, eaw: f64| -> f64 {
                let mut ws = Vec::new();
                for name in &qkv {
                    let base = cs.stats_for(cfg, name, &scaps, stats_opts);
                    let uniform = cs.stats_for(
                        cfg,
                        name,
                        &scaps,
                        StatsOpts {
                            attn_weighted: false,
                            ..stats_opts
                        },
                    );
                    let mixed = mix_attention(
                        &mix_drift(&base, eqr),
                        &mix_drift(&uniform, eqr),
                        eaw,
                    );
                    match watersic_at_rate(
                        teacher.get(name),
                        &mixed,
                        rate_now,
                        &opts.quant,
                        None,
                        opts.subsample_rows.min(32),
                        layer_seed_from_name(name),
                    ) {
                        Ok(q) => ws.push(q.dequant()),
                        Err(_) => return f64::INFINITY,
                    }
                }
                let s_out = attention_block_output(
                    cfg, &ws[0], &ws[1], &ws[2], s_panel, opts.calib_batch, cfg.ctx,
                );
                let d = s_out.sub(&t_out);
                d.data.iter().map(|x| x * x).sum::<f64>() / t_norm.max(1e-300)
            };
            let (q, a) = optimize_mixing(objective, opts.mixing_iters);
            eps_qr = q;
            eps_aw = a;
            report.mixing.push((li, eps_qr, eps_aw));
        }

        // ---- quantize all 7 matrices of the layer in order
        let order: Vec<String> = qkv
            .iter()
            .cloned()
            .chain([
                format!("{p}attn.wo"),
                format!("{p}ffn.w1"),
                format!("{p}ffn.w3"),
                format!("{p}ffn.w2"),
            ])
            .collect();
        if opts.algo == Algo::WaterSic && !opts.mixing {
            // WaterSIC's expensive front-end (drift-stat assembly +
            // dead-feature erasure + damped Cholesky + target solve) is
            // rate-independent, so it is streamed: a producer thread
            // builds front-ends ahead of the budget loop, one matrix at
            // a time with each build pool-parallel inside.  Slots are
            // acquired *before* a build starts and released only after
            // the pair is consumed, so at most `prepare_lookahead`
            // prepared front-ends are ever alive — and the inherently
            // sequential rate assignment keeps assigned rates, and
            // therefore every output bit, identical to the strictly
            // in-order pipeline.  (Adaptive mixing rewrites the QKV
            // statistics mid-loop, so that path prepares inline below.)
            let gate = PrepareWindow::new(opts.prepare_lookahead);
            let scope_res: Result<()> = std::thread::scope(|scope| {
                let (tx, rx) = mpsc::channel::<(Mat, Result<PreparedPair>)>();
                let _close_guard = CloseOnDrop(&gate);
                let gate_ref = &gate;
                let order_ref = &order;
                let scaps_ref = &scaps;
                let cs_ref = &cs;
                let _producer = scope.spawn(move || {
                    for name in order_ref {
                        if !gate_ref.acquire() {
                            return; // consumer bailed out
                        }
                        let stats = cs_ref.stats_for(cfg, name, scaps_ref, stats_opts);
                        let pair = prepare_at_rate(
                            teacher.get(name),
                            &stats,
                            &opts.quant,
                            opts.subsample_rows,
                            layer_seed_from_name(name),
                        );
                        // only Σ_X survives past prepare (the report's
                        // rel-MSE weighting); dropping the other n×n
                        // covariances and the drift term here keeps the
                        // buffered slots as lean as the pairs they gate
                        let LayerStats { sigma_x, .. } = stats;
                        if tx.send((sigma_x, pair)).is_err() {
                            return; // consumer bailed out
                        }
                    }
                });
                // every exit below — return, bail, panic — drops
                // _close_guard, which closes the window and unparks a
                // waiting producer before the scope joins it
                for name in &order {
                    let Ok((sigma_x, pair)) = rx.recv() else {
                        anyhow::bail!("prepare producer exited early");
                    };
                    let step = pair.and_then(|p| {
                        consume_matrix(
                            name,
                            teacher.get(name),
                            &sigma_x,
                            None,
                            Some(p),
                            opts,
                            engine,
                            &mut budget,
                            &mut report,
                            &mut student,
                            &mut quants,
                        )
                    });
                    gate.release();
                    step?;
                }
                Ok(())
            });
            scope_res?;
            report.prepare_peak_pairs = report.prepare_peak_pairs.max(gate.peak());
        } else {
            // baselines and the mixing path: the drift statistics
            // depend only on the per-layer captures, not on the running
            // quantization — assemble all 7 in parallel before the
            // sequential budgeted quantization loop
            let stats_threads =
                crate::util::threadpool::default_threads().min(order.len());
            let stats_list: Vec<LayerStats> = crate::util::threadpool::parallel_map(
                order.clone(),
                stats_threads,
                |name| cs.stats_for(cfg, &name, &scaps, stats_opts),
            );
            for (name, precomputed) in order.iter().zip(stats_list) {
                let is_qkv = name.contains("attn.w") && !name.ends_with("wo");
                let mut stats = precomputed;
                if opts.mixing && opts.algo == Algo::WaterSic && is_qkv {
                    let uniform = cs.stats_for(
                        cfg,
                        name,
                        &scaps,
                        StatsOpts {
                            attn_weighted: false,
                            ..stats_opts
                        },
                    );
                    stats = mix_attention(
                        &mix_drift(&stats, eps_qr),
                        &mix_drift(&uniform, eps_qr),
                        eps_aw,
                    );
                }
                consume_matrix(
                    name,
                    teacher.get(name),
                    &stats.sigma_x,
                    Some(&stats),
                    None,
                    opts,
                    engine,
                    &mut budget,
                    &mut report,
                    &mut student,
                    &mut quants,
                )?;
            }
        }
    }
    report.avg_rate = budget.spent_average(cfg.quantizable_params());

    // ---- optional WaterSIC-FT
    if let Some(ft) = &opts.finetune {
        report.ft_loss_trace = crate::ft::finetune_rescalers(
            cfg,
            &cs.teacher_logits,
            &cs.batches,
            opts.calib_batch,
            &mut student,
            &mut quants,
            ft,
        )?;
    }

    // ---- final input-drift diagnostics (ablation figures)
    let final_caps = cs.student_pass(cfg, &student);
    for li in 0..cfg.n_layers {
        for group in [
            format!("layers.{li}.attn.qkv"),
            format!("layers.{li}.attn.wo"),
            format!("layers.{li}.ffn.in"),
            format!("layers.{li}.ffn.w2"),
        ] {
            let t = cs.teacher_panels(&group);
            let s = student_panels(&final_caps, &group);
            report.input_rel_mse.push((group, panel_rel_mse(&t, &s)));
        }
    }
    report.wall_secs = t0.elapsed().as_secs_f64();

    Ok(QuantizedModel {
        student,
        quants,
        report,
    })
}

/// Total coded bits of a quantized model (rANS streams + scalar side
/// info) — feeds the Fig. 1 size axis.
pub fn coded_bits(qm: &QuantizedModel) -> f64 {
    qm.quants
        .values()
        .map(|q| q.rate_bits * (q.a * q.n) as f64)
        .sum()
}

pub fn quantizable_group(matrix: &str) -> String {
    input_group(matrix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::{forward, ForwardOpts};

    fn setup() -> (ModelConfig, Weights, Corpus) {
        let cfg = ModelConfig::tiny_test();
        let teacher = Weights::random(&cfg, 21);
        let text: String = (0..400)
            .map(|i| format!("alpha beta {} gamma. ", i % 37))
            .collect();
        let corpus = Corpus::from_bytes("test", text.into_bytes());
        (cfg, teacher, corpus)
    }

    fn small_opts(algo: Algo, rate: f64) -> PipelineOpts {
        let mut o = match algo {
            Algo::WaterSic => PipelineOpts::watersic(rate),
            a => PipelineOpts::baseline(a, rate),
        };
        o.calib_windows = 4;
        o.calib_batch = 2;
        o.use_engine = false;
        o.subsample_rows = 16;
        // env-independent: tests must not race a WATERSIC_PREPARE_LOOKAHEAD
        // set in the environment
        o.prepare_lookahead = 2;
        o
    }

    #[test]
    fn watersic_pipeline_end_to_end() {
        let (cfg, teacher, corpus) = setup();
        let qm = quantize_model(
            &cfg,
            &teacher,
            &corpus,
            &small_opts(Algo::WaterSic, 3.0),
            None,
        )
        .unwrap();
        assert_eq!(qm.quants.len(), 7);
        assert!(
            (qm.report.avg_rate - 3.0).abs() < 0.4,
            "avg rate {}",
            qm.report.avg_rate
        );
        // student differs from teacher but is finite and usable
        let toks: Vec<i32> = (0..cfg.ctx).map(|i| (i % 60) as i32).collect();
        let out = forward(&cfg, &qm.student, &toks, 1, cfg.ctx, &ForwardOpts::default());
        assert!(out.logits.is_finite());
    }

    #[test]
    fn streaming_prepare_is_window_invariant() {
        // the lookahead window size is a memory knob, never a numerics
        // knob: every window must produce the identical assigned rates,
        // codes and scales, and the peak never exceeds the window
        let (cfg, teacher, corpus) = setup();
        let mut base = small_opts(Algo::WaterSic, 3.0);
        base.prepare_lookahead = 1; // fully serial reference
        let q1 = quantize_model(&cfg, &teacher, &corpus, &base, None).unwrap();
        assert_eq!(q1.report.prepare_peak_pairs, 1);
        for window in [2usize, 9] {
            let mut o = base.clone();
            o.prepare_lookahead = window;
            let qw = quantize_model(&cfg, &teacher, &corpus, &o, None).unwrap();
            assert!(
                (1..=window).contains(&qw.report.prepare_peak_pairs),
                "window {window}: peak {} pairs",
                qw.report.prepare_peak_pairs
            );
            assert_eq!(q1.report.matrices.len(), qw.report.matrices.len());
            for (m1, mw) in q1.report.matrices.iter().zip(&qw.report.matrices) {
                assert_eq!(m1.name, mw.name);
                assert_eq!(
                    m1.assigned_rate, mw.assigned_rate,
                    "{}: assigned rate must be window-invariant",
                    m1.name
                );
                assert_eq!(m1.entropy_bits, mw.entropy_bits);
                assert_eq!(m1.rate_bits, mw.rate_bits);
            }
            for (name, q) in &q1.quants {
                let qq = &qw.quants[name];
                assert_eq!(q.z, qq.z, "{name}: codes must be window-invariant");
                assert_eq!(q.alphas, qq.alphas);
                assert_eq!(q.gammas, qq.gammas);
                assert_eq!(q.t, qq.t);
            }
        }
    }

    #[test]
    fn watersic_beats_huffgptq_at_low_rate() {
        let (cfg, teacher, corpus) = setup();
        let rate = 2.5;
        let ws = quantize_model(&cfg, &teacher, &corpus,
                                &small_opts(Algo::WaterSic, rate), None).unwrap();
        let hg = quantize_model(&cfg, &teacher, &corpus,
                                &small_opts(Algo::HuffGptq, rate), None).unwrap();
        let avg = |qm: &QuantizedModel| {
            qm.report.matrices.iter().map(|m| m.rel_mse_weights).sum::<f64>()
                / qm.report.matrices.len() as f64
        };
        assert!(
            avg(&ws) < avg(&hg),
            "WaterSIC {:.4} must beat Huffman-GPTQ {:.4}",
            avg(&ws),
            avg(&hg)
        );
    }

    #[test]
    fn budget_keeps_average_near_target() {
        let (cfg, teacher, corpus) = setup();
        for rate in [2.0, 4.0] {
            let qm = quantize_model(&cfg, &teacher, &corpus,
                                    &small_opts(Algo::HuffGptq, rate), None).unwrap();
            assert!(
                (qm.report.avg_rate - rate).abs() < 0.35,
                "rate {rate}: got {}",
                qm.report.avg_rate
            );
        }
    }

    #[test]
    fn rtn_pipeline_runs() {
        let (cfg, teacher, corpus) = setup();
        let qm = quantize_model(&cfg, &teacher, &corpus,
                                &small_opts(Algo::Rtn { bits: 4 }, 4.0), None).unwrap();
        assert_eq!(qm.report.matrices.len(), 7);
        for m in &qm.report.matrices {
            assert!(m.rel_mse_weights.is_finite());
        }
    }

    #[test]
    fn ft_hook_improves_or_matches() {
        let (cfg, teacher, corpus) = setup();
        let mut o = small_opts(Algo::WaterSic, 3.0);
        let qm0 = quantize_model(&cfg, &teacher, &corpus, &o, None).unwrap();
        o.finetune = Some(crate::ft::FtOpts {
            steps: 10,
            peak_lr: 5e-3,
            min_lr: 1e-4,
        });
        let qm1 = quantize_model(&cfg, &teacher, &corpus, &o, None).unwrap();
        assert!(!qm1.report.ft_loss_trace.is_empty());
        // evaluate KL on the calibration batches (in-sample but fair
        // between the two variants)
        let windows = corpus.calib_windows(4, cfg.ctx, 99);
        let kl0 = crate::eval::kl_to_teacher(&cfg, &teacher, &qm0.student, &windows);
        let kl1 = crate::eval::kl_to_teacher(&cfg, &teacher, &qm1.student, &windows);
        assert!(kl1 < kl0 * 1.05, "FT should not hurt: {kl0} → {kl1}");
    }
}
