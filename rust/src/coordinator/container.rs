//! Compressed-model container (`.wsic`): the deployable artifact of the
//! pipeline.  Per quantized matrix it stores the rANS-coded integer
//! stream plus the continuous side information (α, γ fused per column;
//! t per row), and reconstructs bit-identical Ŵ on load.
//!
//! Layout (all integers little-endian, varint where noted):
//!   magic "WSIC" + version u8
//!   model-name (varint len + utf8)
//!   matrix count (varint)
//!   per matrix:
//!     name, a, n (varints)
//!     col_scale[n] f32 (α_j·γ_j fused — the paper's A·Γ fusion)
//!     t[a] f32
//!     dead-col count + indices (varints)
//!     rANS stream (varint len + bytes)

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::entropy::bitio::{get_varint, put_varint};
use crate::entropy::rans::Rans;
use crate::entropy::Codec;
use crate::quant::LayerQuant;

const MAGIC: &[u8] = b"WSIC";
const VERSION: u8 = 1;
/// Upper bound on a single matrix's code count (2²⁸ ≈ 268M weights —
/// far above any layer this system serves).  A degenerate rANS table
/// can legitimately encode astronomically many symbols in a handful of
/// stream bytes, so the stream length cannot bound the decode count; a
/// corrupted a×n past this cap must bail before the decode loop
/// materializes it.
const MAX_MATRIX_CODES: usize = 1 << 28;

pub struct Container {
    pub model_name: String,
    pub quants: BTreeMap<String, LayerQuant>,
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Read `len` bytes at `*pos`, guarding the offset arithmetic: a
/// corrupted varint length must come back as an error, never as an
/// overflow panic (debug) or a wrapped-range read (release).
fn get_bytes<'a>(bytes: &'a [u8], pos: &mut usize, len: usize, what: &str) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(len)
        .with_context(|| format!("{what} length overflows"))?;
    let s = bytes
        .get(*pos..end)
        .with_context(|| format!("truncated {what}"))?;
    *pos = end;
    Ok(s)
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_varint(bytes, pos)? as usize;
    let s = get_bytes(bytes, pos, len, "string")?;
    Ok(String::from_utf8(s.to_vec())?)
}

impl Container {
    pub fn new(model_name: &str, quants: BTreeMap<String, LayerQuant>) -> Self {
        Container {
            model_name: model_name.to_string(),
            quants,
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        put_str(&mut out, &self.model_name);
        put_varint(&mut out, self.quants.len() as u64);
        for (name, q) in &self.quants {
            put_str(&mut out, name);
            put_varint(&mut out, q.a as u64);
            put_varint(&mut out, q.n as u64);
            for j in 0..q.n {
                out.extend_from_slice(
                    &((q.alphas[j] * q.gammas[j]) as f32).to_le_bytes(),
                );
            }
            for i in 0..q.a {
                out.extend_from_slice(&(q.t[i] as f32).to_le_bytes());
            }
            put_varint(&mut out, q.dead_cols.len() as u64);
            for &d in &q.dead_cols {
                put_varint(&mut out, d as u64);
            }
            let stream = Rans.encode(&q.z);
            put_varint(&mut out, stream.len() as u64);
            out.extend_from_slice(&stream);
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Container> {
        if bytes.len() < 5 || &bytes[..4] != MAGIC {
            bail!("bad container magic");
        }
        if bytes[4] != VERSION {
            bail!("unsupported container version {}", bytes[4]);
        }
        let mut pos = 5;
        let model_name = get_str(bytes, &mut pos)?;
        let count = get_varint(bytes, &mut pos)? as usize;
        let mut quants = BTreeMap::new();
        for _ in 0..count {
            let name = get_str(bytes, &mut pos)?;
            let a = get_varint(bytes, &mut pos)? as usize;
            let n = get_varint(bytes, &mut pos)? as usize;
            // plausibility bounds before any allocation: each scale/t
            // entry needs 4 bytes, each dead index ≥ 1 byte — a huge
            // header count on a short buffer is corruption, and must
            // not drive a giant Vec reservation
            let left = bytes.len() - pos;
            if n > left / 4 {
                bail!("corrupt header: {n} column scales in {left} bytes");
            }
            let mut col_scale = Vec::with_capacity(n);
            for _ in 0..n {
                let b = get_bytes(bytes, &mut pos, 4, "scales")?;
                col_scale.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64);
            }
            if a > (bytes.len() - pos) / 4 {
                bail!("corrupt header: {a} row rescalers in {} bytes", bytes.len() - pos);
            }
            let mut t = Vec::with_capacity(a);
            for _ in 0..a {
                let b = get_bytes(bytes, &mut pos, 4, "t")?;
                t.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64);
            }
            let ndead = get_varint(bytes, &mut pos)? as usize;
            if ndead > bytes.len() - pos {
                bail!("corrupt header: {ndead} dead columns in {} bytes", bytes.len() - pos);
            }
            let mut dead_cols = Vec::with_capacity(ndead);
            for _ in 0..ndead {
                dead_cols.push(get_varint(bytes, &mut pos)? as usize);
            }
            let slen = get_varint(bytes, &mut pos)? as usize;
            let stream = get_bytes(bytes, &mut pos, slen, "stream")?;
            let codes = a
                .checked_mul(n)
                .filter(|&c| c <= MAX_MATRIX_CODES)
                .with_context(|| {
                    format!("corrupt header: {a}x{n} matrix is implausibly large")
                })?;
            let z = Rans.decode(stream, codes)?;
            quants.insert(
                name,
                LayerQuant {
                    a,
                    n,
                    z,
                    // α·γ are fused on save; reconstruct with γ = 1
                    alphas: col_scale,
                    gammas: vec![1.0; n],
                    t,
                    entropy_bits: 0.0,
                    rate_bits: 0.0,
                    dead_cols,
                },
            );
        }
        Ok(Container { model_name, quants })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Container> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    /// Total size in bytes (the Fig. 1 x-axis, measured not estimated).
    pub fn size_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    /// Bytes of the code plane alone: the rANS streams, without the
    /// continuous side information (scales, rescalers, headers).  The
    /// coded serving path's resident-byte telemetry compares against
    /// this — its bit-packed panel codes plus decode side info should
    /// land within a small factor of the entropy-coded artifact.
    pub fn code_bytes(&self) -> usize {
        self.quants.values().map(|q| Rans.encode(&q.z).len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fake_quant(a: usize, n: usize, seed: u64) -> LayerQuant {
        let mut rng = Rng::new(seed);
        LayerQuant {
            a,
            n,
            z: (0..a * n)
                .map(|_| (rng.gaussian() * 2.0).round() as i32)
                .collect(),
            alphas: (0..n).map(|_| 0.1 + rng.uniform()).collect(),
            gammas: (0..n).map(|_| 0.8 + 0.2 * rng.uniform()).collect(),
            t: (0..a).map(|_| 0.9 + 0.2 * rng.uniform()).collect(),
            entropy_bits: 2.0,
            rate_bits: 2.1,
            dead_cols: vec![3],
            }
    }

    #[test]
    fn roundtrip_reconstructs_w_hat() {
        let mut quants = BTreeMap::new();
        quants.insert("layers.0.attn.wq".to_string(), fake_quant(16, 24, 1));
        quants.insert("layers.0.ffn.w2".to_string(), fake_quant(8, 32, 2));
        let c = Container::new("picollama_s", quants);
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.model_name, "picollama_s");
        assert_eq!(c2.quants.len(), 2);
        for (name, q) in &c.quants {
            let q2 = &c2.quants[name];
            assert_eq!(q.z, q2.z);
            assert_eq!(q2.dead_cols, q.dead_cols);
            // Ŵ identical to f32 precision (scales stored as f32)
            let w1 = q.dequant();
            let w2 = q2.dequant();
            assert!(w1.sub(&w2).max_abs() < 1e-5, "{name}");
        }
    }

    #[test]
    fn container_size_tracks_entropy() {
        // low-entropy codes must compress much smaller than high-entropy
        let mut low = BTreeMap::new();
        let mut q = fake_quant(64, 64, 3);
        q.z.iter_mut().for_each(|z| *z = 0);
        low.insert("m".to_string(), q);
        let mut high = BTreeMap::new();
        let mut rng = Rng::new(4);
        let mut q2 = fake_quant(64, 64, 5);
        q2.z.iter_mut()
            .for_each(|z| *z = (rng.gaussian() * 40.0) as i32);
        high.insert("m".to_string(), q2);
        let s_low = Container::new("x", low).size_bytes();
        let s_high = Container::new("x", high).size_bytes();
        assert!(s_low < s_high / 2, "{s_low} vs {s_high}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Container::from_bytes(b"nope").is_err());
        let mut quants = BTreeMap::new();
        quants.insert("m".to_string(), fake_quant(4, 4, 9));
        let mut bytes = Container::new("x", quants).to_bytes();
        bytes[4] = 99; // bad version
        assert!(Container::from_bytes(&bytes).is_err());
    }

    /// Start of a malicious header: magic + version, next read is the
    /// model-name varint.
    fn header_prefix() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.push(VERSION);
        b
    }

    #[test]
    fn overflowing_string_length_errors_not_panics() {
        // a u64::MAX name length must fail the checked offset add, not
        // overflow-panic (debug) or wrap into a bogus range (release)
        let mut bytes = header_prefix();
        put_varint(&mut bytes, u64::MAX);
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn overflowing_stream_length_errors_not_panics() {
        // a valid header up to the rANS stream, whose varint length is
        // u64::MAX with no bytes behind it
        let mut bytes = header_prefix();
        put_varint(&mut bytes, 1); // model name "x"
        bytes.push(b'x');
        put_varint(&mut bytes, 1); // one matrix
        put_varint(&mut bytes, 1); // name "m"
        bytes.push(b'm');
        put_varint(&mut bytes, 1); // a
        put_varint(&mut bytes, 1); // n
        bytes.extend_from_slice(&1.0f32.to_le_bytes()); // col scale
        bytes.extend_from_slice(&1.0f32.to_le_bytes()); // t
        put_varint(&mut bytes, 0); // no dead cols
        put_varint(&mut bytes, u64::MAX); // stream length
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn giant_header_counts_error_before_allocating() {
        // a×n dimensions far past the buffer (and past usize multiply
        // range) must bail on the plausibility guards / checked_mul
        // instead of reserving giant Vecs or panicking
        for (a, n) in [
            (u64::MAX, 2u64),
            (2, u64::MAX),
            (1 << 40, 1 << 40),
            (1 << 20, 1),
        ] {
            let mut bytes = header_prefix();
            put_varint(&mut bytes, 1);
            bytes.push(b'x');
            put_varint(&mut bytes, 1);
            put_varint(&mut bytes, 1);
            bytes.push(b'm');
            put_varint(&mut bytes, a);
            put_varint(&mut bytes, n);
            assert!(Container::from_bytes(&bytes).is_err(), "a={a} n={n}");
        }
    }

    #[test]
    fn truncated_tail_errors_everywhere() {
        // chop a valid container at every byte boundary: each prefix
        // must error cleanly (never panic)
        let mut quants = BTreeMap::new();
        quants.insert("m".to_string(), fake_quant(6, 5, 11));
        let bytes = Container::new("x", quants).to_bytes();
        for cut in 0..bytes.len() {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }
}
