//! The Layer-3 coordinator: the sequential per-layer quantization
//! pipeline with global rate budgeting, drift-aware calibration refresh,
//! joint QKV quantization with adaptive mixing, optional post-quant
//! finetuning, and the compressed-model container format.

pub mod container;
pub mod pipeline;

pub use pipeline::{quantize_model, Algo, PipelineOpts, PipelineReport, QuantizedModel};
