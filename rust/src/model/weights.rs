//! Weight storage: named f64 matrices/vectors, loaded from the
//! `artifacts/models/<name>/*.npy` directory written by the build-time
//! trainer, mutated in place by the quantization pipeline.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::container::Container;
use crate::linalg::gemm::{
    matmul_coded, matmul_prepacked, CodedPanel, CodedPart, Precision, PrepackedB,
};
use crate::linalg::Mat;
use crate::quant::LayerQuant;
use crate::util::npy::{Npy, NpyData};

use super::ModelConfig;

/// Named parameters; 2-D ones as `Mat`, 1-D gains as vectors.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub mats: BTreeMap<String, Mat>,
    pub vecs: BTreeMap<String, Vec<f64>>,
}

impl Weights {
    /// Load all `.npy` files of a model directory.
    pub fn load(dir: &Path, cfg: &ModelConfig) -> Result<Weights> {
        let mut w = Weights::default();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
        {
            let path = entry?.path();
            let fname = path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let Some(name) = fname.strip_suffix(".npy") else {
                continue;
            };
            let npy = Npy::read(&path)?;
            let data = match &npy.data {
                NpyData::F32(v) => v.iter().map(|&x| x as f64).collect::<Vec<f64>>(),
                NpyData::I32(_) => bail!("unexpected int weights in {fname}"),
            };
            match npy.shape.len() {
                1 => {
                    w.vecs.insert(name.to_string(), data);
                }
                2 => {
                    w.mats.insert(
                        name.to_string(),
                        Mat::from_vec(npy.shape[0], npy.shape[1], data),
                    );
                }
                d => bail!("{fname}: unsupported rank {d}"),
            }
        }
        w.validate(cfg)?;
        Ok(w)
    }

    /// Structural validation against the config.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in &cfg.quantizable {
            let m = self
                .mats
                .get(name)
                .with_context(|| format!("missing weight {name}"))?;
            let (a, n) = cfg.shape_of(name);
            if (m.rows, m.cols) != (a, n) {
                bail!(
                    "{name}: shape {}x{} != expected {a}x{n}",
                    m.rows,
                    m.cols
                );
            }
        }
        for req in ["embed", "head"] {
            if !self.mats.contains_key(req) {
                bail!("missing weight {req}");
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> &Mat {
        &self.mats[name]
    }

    pub fn get_vec(&self, name: &str) -> &[f64] {
        &self.vecs[name]
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        self.mats.insert(name.to_string(), m);
    }

    /// Random-initialized weights for tests (matches python init scheme).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut w = Weights::default();
        let d = cfg.d_model;
        let names: Vec<String> = {
            let mut v = vec!["embed".to_string(), "head".to_string()];
            for i in 0..cfg.n_layers {
                let p = format!("layers.{i}.");
                for s in ["attn.wq", "attn.wk", "attn.wv", "attn.wo",
                          "ffn.w1", "ffn.w3", "ffn.w2"] {
                    v.push(format!("{p}{s}"));
                }
                w.vecs.insert(format!("{p}norm1"), vec![1.0; d]);
                w.vecs.insert(format!("{p}norm2"), vec![1.0; d]);
            }
            w.vecs.insert("final_norm".to_string(), vec![1.0; d]);
            v
        };
        for name in names {
            let (a, n) = cfg.shape_of(&name);
            let scale = 1.0 / (n.max(1) as f64).sqrt();
            w.mats.insert(
                name,
                Mat::from_fn(a, n, |_, _| scale * rng.gaussian()),
            );
        }
        w
    }

    /// Flattened f32 buffers in `param_order` — the exact argument list
    /// of the AOT forward artifact.
    pub fn flatten_f32(&self, order: &[String]) -> Vec<Vec<f32>> {
        order
            .iter()
            .map(|name| {
                if let Some(m) = self.mats.get(name) {
                    m.to_f32()
                } else {
                    self.vecs[name].iter().map(|&x| x as f32).collect()
                }
            })
            .collect()
    }
}

/// Weights plus per-matrix prepacked projection panels — the serving
/// path's model representation.  Every matrix the forward routes
/// through a projection GEMM (per-layer QKV/wo/FFN and the LM head) is
/// packed **once** at load time via [`PrepackedB::pack_nt`]; batched
/// forwards then skip the per-call B-pack entirely.  The raw f64
/// storage of a packed matrix is dropped right after packing (the
/// packed forward never reads it), so serving holds one copy of each
/// weight, not two; only the embedding table (a row lookup) and the
/// norm gains remain in [`Weights`].
///
/// The pack precision is fixed at build time (normally the
/// `WATERSIC_PRECISION` engine option); a packed forward always runs
/// the blocked driver at that precision, so its outputs are
/// bit-identical across thread counts, batch compositions, and
/// dispatch rungs (see [`PrepackedB`]).
pub struct PackedWeights {
    /// embed + norm gains (+ anything never routed through a
    /// projection); packed matrices are removed from `mats`
    pub weights: Weights,
    pub packed: BTreeMap<String, PackedProjection>,
    pub precision: Precision,
}

/// One projection operand of the packed forward, in either resident
/// form: eagerly dequantized panels ([`PrepackedB`]) or the quantized
/// codes themselves ([`CodedPanel`], decoded per KC block inside the
/// pack stage).  The two project **bit-identically** — `matmul_coded`
/// reproduces `matmul_prepacked` over the eager dequant exactly — so
/// the choice is purely a residency/bandwidth trade, switched at load
/// time by the `WATERSIC_SERVE_WEIGHTS` engine option.
pub enum PackedProjection {
    Dense(PrepackedB),
    Coded(CodedPanel),
}

impl PackedProjection {
    /// x · Wᵀ through whichever resident form this projection holds.
    pub fn project(&self, x: &Mat) -> Mat {
        match self {
            PackedProjection::Dense(pb) => matmul_prepacked(x, pb),
            PackedProjection::Coded(cp) => matmul_coded(x, cp),
        }
    }

    /// Resident bytes of this operand (panels or codes + side info).
    pub fn bytes(&self) -> usize {
        match self {
            PackedProjection::Dense(pb) => pb.bytes(),
            PackedProjection::Coded(cp) => cp.bytes(),
        }
    }

    pub fn is_coded(&self) -> bool {
        matches!(self, PackedProjection::Coded(_))
    }
}

impl PackedWeights {
    /// Prepack every projection matrix of `weights` for the given
    /// model architecture.  The packs are **decode-shaped**: the three
    /// QKV matrices fuse into one `attn.qkv` operand ([wq; wk; wv],
    /// 3d × d) and the two FFN input matrices into one `ffn.w13`
    /// ([w1; w3], 2f × d), so a forward issues 4 projection GEMMs per
    /// layer instead of 7 — at decode widths (1–16 rows) the driver
    /// dispatch and activation re-reads dominate, so fusing is most of
    /// the win.  Each fused output column's reduction order is fixed by
    /// the KC grid alone, so the split halves are bit-identical to
    /// separate per-matrix products.
    pub fn new(
        cfg: &ModelConfig,
        mut weights: Weights,
        prec: Precision,
    ) -> PackedWeights {
        let mut packed = BTreeMap::new();
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            let fused_groups: [(&str, &[&str]); 2] = [
                ("attn.qkv", &["attn.wq", "attn.wk", "attn.wv"]),
                ("ffn.w13", &["ffn.w1", "ffn.w3"]),
            ];
            for (fused, parts) in fused_groups {
                let names: Vec<String> =
                    parts.iter().map(|s| format!("{p}{s}")).collect();
                let stacked = Self::stack_rows(&weights, &names);
                packed.insert(
                    format!("{p}{fused}"),
                    PackedProjection::Dense(PrepackedB::pack_nt(&stacked, prec)),
                );
                for n in &names {
                    weights.mats.remove(n);
                }
            }
            for s in ["attn.wo", "ffn.w2"] {
                let name = format!("{p}{s}");
                let pb = PrepackedB::pack_nt(weights.get(&name), prec);
                weights.mats.remove(&name);
                packed.insert(name, PackedProjection::Dense(pb));
            }
        }
        let pb = PrepackedB::pack_nt(weights.get("head"), prec);
        weights.mats.remove("head");
        packed.insert("head".to_string(), PackedProjection::Dense(pb));
        PackedWeights {
            weights,
            packed,
            precision: prec,
        }
    }

    /// Stack same-width matrices on top of each other — the fused
    /// projection operand ([wq; wk; wv] etc.).
    fn stack_rows(w: &Weights, names: &[String]) -> Mat {
        let mats: Vec<&Mat> = names.iter().map(|n| w.get(n)).collect();
        Self::stack_mats(&mats)
    }

    fn stack_mats(mats: &[&Mat]) -> Mat {
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut out = Mat::zeros(rows, cols);
        let mut r0 = 0;
        for m in mats {
            assert_eq!(m.cols, cols, "fused operands must share width");
            for r in 0..m.rows {
                out.row_mut(r0 + r).copy_from_slice(m.row(r));
            }
            r0 += m.rows;
        }
        out
    }

    /// Dequantize a `.wsic` container over the base weights (embed /
    /// norms / head come from `base`; quantized matrices are
    /// reconstructed), then prepack — the container-to-serving load
    /// path.  Quantized matrices are dequantized straight into the
    /// student (the base copies they replace are never cloned), so the
    /// load peak stays near one model's worth of weights.
    pub fn from_container(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
    ) -> Result<PackedWeights> {
        for name in container.quants.keys() {
            if !base.mats.contains_key(name) {
                bail!("container matrix {name} unknown to the base weights");
            }
        }
        let mut student = Weights {
            mats: BTreeMap::new(),
            vecs: base.vecs.clone(),
        };
        for (name, m) in &base.mats {
            let rebuilt = match container.quants.get(name) {
                Some(q) => q.dequant(),
                None => m.clone(),
            };
            student.mats.insert(name.clone(), rebuilt);
        }
        student.validate(cfg)?;
        Ok(Self::new(cfg, student, prec))
    }

    /// Build the serving representation straight from the container's
    /// quantized codes: each fully-quantized projection becomes a
    /// [`CodedPanel`] (codes stay bit-packed resident; dequant happens
    /// per KC block inside the pack stage), so resident weight bytes
    /// drop to roughly the artifact size.  A fused group with any
    /// unquantized member — and any matrix absent from the container —
    /// falls back to the eager [`PrepackedB`] form.  Either way every
    /// projection is **bit-identical** to [`PackedWeights::from_container`]
    /// at the same precision, so forwards match to the bit.
    pub fn from_container_coded(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
    ) -> Result<PackedWeights> {
        base.validate(cfg)?;
        for (name, q) in &container.quants {
            if !base.mats.contains_key(name) {
                bail!("container matrix {name} unknown to the base weights");
            }
            let (a, n) = cfg.shape_of(name);
            if (q.a, q.n) != (a, n) {
                bail!("{name}: quantized shape {}x{} != expected {a}x{n}", q.a, q.n);
            }
        }
        let mut weights = Weights {
            mats: base.mats.clone(),
            vecs: base.vecs.clone(),
        };
        let mut packed = BTreeMap::new();
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            let fused_groups: [(&str, &[&str]); 2] = [
                ("attn.qkv", &["attn.wq", "attn.wk", "attn.wv"]),
                ("ffn.w13", &["ffn.w1", "ffn.w3"]),
            ];
            for (fused, parts) in fused_groups {
                let names: Vec<String> =
                    parts.iter().map(|s| format!("{p}{s}")).collect();
                packed.insert(
                    format!("{p}{fused}"),
                    Self::coded_or_dense(&mut weights, container, &names, prec)?,
                );
            }
            for s in ["attn.wo", "ffn.w2"] {
                let name = format!("{p}{s}");
                let proj =
                    Self::coded_or_dense(&mut weights, container, std::slice::from_ref(&name), prec)?;
                packed.insert(name, proj);
            }
        }
        let head = "head".to_string();
        let proj =
            Self::coded_or_dense(&mut weights, container, std::slice::from_ref(&head), prec)?;
        packed.insert(head, proj);
        Ok(PackedWeights {
            weights,
            packed,
            precision: prec,
        })
    }

    /// One projection group of the coded load path: a [`CodedPanel`]
    /// when every member is quantized, the eager dense pack otherwise.
    /// The members' raw storage is dropped from `weights` either way.
    fn coded_or_dense(
        weights: &mut Weights,
        container: &Container,
        names: &[String],
        prec: Precision,
    ) -> Result<PackedProjection> {
        let proj = if names.iter().all(|n| container.quants.contains_key(n)) {
            let quants: Vec<&LayerQuant> =
                names.iter().map(|n| &container.quants[n]).collect();
            let parts: Vec<CodedPart> = quants
                .iter()
                .map(|q| CodedPart {
                    z: &q.z,
                    t: &q.t,
                    gammas: &q.gammas,
                    alphas: &q.alphas,
                    rows: q.a,
                    cols: q.n,
                })
                .collect();
            PackedProjection::Coded(
                CodedPanel::pack_nt_parts(&parts, prec)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", names.join("+")))?,
            )
        } else {
            // mixed or unquantized group: eager dequant, bit-compatible
            // dense pack (matmul_coded ≡ matmul_prepacked over dequant)
            let mats: Vec<Mat> = names
                .iter()
                .map(|n| match container.quants.get(n) {
                    Some(q) => q.dequant(),
                    None => weights.get(n).clone(),
                })
                .collect();
            let refs: Vec<&Mat> = mats.iter().collect();
            PackedProjection::Dense(PrepackedB::pack_nt(&Self::stack_mats(&refs), prec))
        };
        for n in names {
            weights.mats.remove(n);
        }
        Ok(proj)
    }

    /// Projection through the prepacked panels: x · Wᵀ for the named
    /// matrix, bit-identical to the pack-per-call driver.  QKV and FFN
    /// input matrices live only in fused form — use
    /// [`PackedWeights::project_qkv`] / [`PackedWeights::project_ffn_in`].
    pub fn project(&self, x: &Mat, name: &str) -> Mat {
        self.packed[name].project(x)
    }

    /// Fused QKV projection: one GEMM against the `attn.qkv` panels,
    /// split into (q, k, v).  Bit-identical to three separate
    /// projections — the driver's per-column independence.
    pub fn project_qkv(&self, x: &Mat, layer_prefix: &str) -> (Mat, Mat, Mat) {
        let fused = self.packed[&format!("{layer_prefix}attn.qkv")].project(x);
        let d = fused.cols / 3;
        (
            Self::col_slice(&fused, 0, d),
            Self::col_slice(&fused, d, d),
            Self::col_slice(&fused, 2 * d, d),
        )
    }

    /// Fused FFN input projection: one GEMM against the `ffn.w13`
    /// panels, split into (w1·x, w3·x).
    pub fn project_ffn_in(&self, x: &Mat, layer_prefix: &str) -> (Mat, Mat) {
        let fused = self.packed[&format!("{layer_prefix}ffn.w13")].project(x);
        let f = fused.cols / 2;
        (Self::col_slice(&fused, 0, f), Self::col_slice(&fused, f, f))
    }

    fn col_slice(m: &Mat, j0: usize, w: usize) -> Mat {
        let mut out = Mat::zeros(m.rows, w);
        for r in 0..m.rows {
            out.row_mut(r).copy_from_slice(&m.row(r)[j0..j0 + w]);
        }
        out
    }

    /// Total bytes held by the packed projections (load-time telemetry):
    /// eager panel bytes for dense entries, code-plane + side-info bytes
    /// for coded ones.
    pub fn packed_bytes(&self) -> usize {
        self.packed.values().map(|p| p.bytes()).sum()
    }

    /// How many projections are serving straight from quantized codes.
    pub fn coded_count(&self) -> usize {
        self.packed.values().filter(|p| p.is_coded()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 1);
        w.validate(&cfg).unwrap();
        assert_eq!(w.get("layers.0.ffn.w1").rows, 32);
        assert_eq!(w.get_vec("layers.0.norm1").len(), 16);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 2);
        let dir = std::env::temp_dir().join("wsic_weights_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (name, m) in &w.mats {
            Npy::f32(vec![m.rows, m.cols], m.to_f32())
                .write(&dir.join(format!("{name}.npy")))
                .unwrap();
        }
        for (name, v) in &w.vecs {
            Npy::f32(vec![v.len()], v.iter().map(|&x| x as f32).collect())
                .write(&dir.join(format!("{name}.npy")))
                .unwrap();
        }
        let w2 = Weights::load(&dir, &cfg).unwrap();
        assert_eq!(w.mats.len(), w2.mats.len());
        let a = w.get("layers.0.attn.wq");
        let b = w2.get("layers.0.attn.wq");
        assert!(a.sub(b).max_abs() < 1e-6); // f32 roundtrip tolerance
    }

    #[test]
    fn packed_weights_project_matches_plain() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 11);
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        // decode-shaped: qkv + w13 + wo + w2 per layer, plus the head
        assert_eq!(pw.packed.len(), 4 * cfg.n_layers + 1);
        assert!(pw.packed_bytes() > 0);
        let mut rng = crate::util::rng::Rng::new(3);
        let x = Mat::from_fn(10, cfg.d_model, |_, _| rng.gaussian());
        let y = pw.project(&x, "layers.0.attn.wo");
        // k = d_model ≤ KC and f64 ⇒ the serial dot of the plain small
        // path reduces in the same order as the single-KC-block packed
        // tile: bitwise equality, not just tolerance
        let y_ref = crate::linalg::gemm::matmul_nt(&x, w.get("layers.0.attn.wo"));
        assert_eq!(y.data, y_ref.data);
    }

    #[test]
    fn fused_projections_bit_identical_to_separate() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 21);
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        let mut rng = crate::util::rng::Rng::new(5);
        // decode-width (1 row) and batch-width activations
        for rows in [1usize, 9] {
            let x = Mat::from_fn(rows, cfg.d_model, |_, _| rng.gaussian());
            let (q, k, v) = pw.project_qkv(&x, "layers.0.");
            for (got, name) in
                [(&q, "attn.wq"), (&k, "attn.wk"), (&v, "attn.wv")]
            {
                let want = crate::linalg::gemm::matmul_nt(
                    &x,
                    w.get(&format!("layers.0.{name}")),
                );
                assert_eq!(got.data, want.data, "{name} ({rows} rows)");
            }
            let (g1, g3) = pw.project_ffn_in(&x, "layers.0.");
            for (got, name) in [(&g1, "ffn.w1"), (&g3, "ffn.w3")] {
                let want = crate::linalg::gemm::matmul_nt(
                    &x,
                    w.get(&format!("layers.0.{name}")),
                );
                assert_eq!(got.data, want.data, "{name} ({rows} rows)");
            }
        }
    }

    fn fake_quant(a: usize, n: usize, seed: u64) -> crate::quant::LayerQuant {
        let mut rng = crate::util::rng::Rng::new(seed);
        crate::quant::LayerQuant {
            a,
            n,
            z: (0..a * n)
                .map(|_| (rng.gaussian() * 3.0).round() as i32)
                .collect(),
            alphas: (0..n).map(|_| 0.1 + rng.uniform()).collect(),
            gammas: vec![1.0; n],
            t: (0..a).map(|_| 0.9 + 0.2 * rng.uniform()).collect(),
            entropy_bits: 2.0,
            rate_bits: 2.1,
            dead_cols: vec![],
        }
    }

    /// A container quantizing every projection of the tiny config.
    fn full_container(cfg: &ModelConfig) -> Container {
        let mut quants = BTreeMap::new();
        for (i, name) in cfg.quantizable.iter().enumerate() {
            let (a, n) = cfg.shape_of(name);
            quants.insert(name.clone(), fake_quant(a, n, 100 + i as u64));
        }
        Container::new(&cfg.name, quants)
    }

    #[test]
    fn coded_load_projects_bit_identical_to_dequant_load() {
        // the serving-mode pin: both container load paths must project
        // bit-identically (coded decode ≡ eager dequant + pack), with
        // the head (unquantized here) falling back to the dense form
        let cfg = ModelConfig::tiny_test();
        let base = Weights::random(&cfg, 31);
        let container = full_container(&cfg);
        let mut rng = crate::util::rng::Rng::new(7);
        for prec in [Precision::F64, Precision::F32] {
            let pw_deq =
                PackedWeights::from_container(&cfg, &base, &container, prec).unwrap();
            let pw_cod =
                PackedWeights::from_container_coded(&cfg, &base, &container, prec)
                    .unwrap();
            assert_eq!(pw_cod.packed.len(), pw_deq.packed.len());
            // qkv + w13 + wo + w2 coded per layer; head stays dense
            assert_eq!(pw_cod.coded_count(), 4 * cfg.n_layers);
            assert_eq!(pw_deq.coded_count(), 0);
            assert!(
                pw_cod.packed_bytes() < pw_deq.packed_bytes(),
                "coded {} vs dequant {} resident bytes",
                pw_cod.packed_bytes(),
                pw_deq.packed_bytes()
            );
            for rows in [1usize, 9] {
                let x = Mat::from_fn(rows, cfg.d_model, |_, _| rng.gaussian());
                let (q1, k1, v1) = pw_deq.project_qkv(&x, "layers.0.");
                let (q2, k2, v2) = pw_cod.project_qkv(&x, "layers.0.");
                assert_eq!(q1.data, q2.data);
                assert_eq!(k1.data, k2.data);
                assert_eq!(v1.data, v2.data);
                let (a1, b1) = pw_deq.project_ffn_in(&x, "layers.0.");
                let (a2, b2) = pw_cod.project_ffn_in(&x, "layers.0.");
                assert_eq!(a1.data, a2.data);
                assert_eq!(b1.data, b2.data);
                for name in ["layers.0.attn.wo", "layers.0.ffn.w2"] {
                    assert_eq!(
                        pw_deq.project(&x, name).data,
                        pw_cod.project(&x, name).data,
                        "{name}"
                    );
                }
                let xh = Mat::from_fn(rows, cfg.d_model, |_, _| rng.gaussian());
                assert_eq!(
                    pw_deq.project(&xh, "head").data,
                    pw_cod.project(&xh, "head").data
                );
            }
        }
    }

    #[test]
    fn coded_load_mixed_group_falls_back_dense() {
        // drop one QKV member from the container: the fused group can't
        // serve coded, but the projection must still match the dequant
        // path bit for bit through the dense fallback
        let cfg = ModelConfig::tiny_test();
        let base = Weights::random(&cfg, 33);
        let mut container = full_container(&cfg);
        container.quants.remove("layers.0.attn.wk");
        let pw_deq =
            PackedWeights::from_container(&cfg, &base, &container, Precision::F64)
                .unwrap();
        let pw_cod =
            PackedWeights::from_container_coded(&cfg, &base, &container, Precision::F64)
                .unwrap();
        assert!(!pw_cod.packed["layers.0.attn.qkv"].is_coded());
        assert!(pw_cod.packed["layers.0.ffn.w13"].is_coded());
        let mut rng = crate::util::rng::Rng::new(9);
        let x = Mat::from_fn(5, cfg.d_model, |_, _| rng.gaussian());
        let (q1, k1, v1) = pw_deq.project_qkv(&x, "layers.0.");
        let (q2, k2, v2) = pw_cod.project_qkv(&x, "layers.0.");
        assert_eq!(q1.data, q2.data);
        assert_eq!(k1.data, k2.data);
        assert_eq!(v1.data, v2.data);
    }

    #[test]
    fn coded_load_rejects_wrong_shapes() {
        let cfg = ModelConfig::tiny_test();
        let base = Weights::random(&cfg, 35);
        let mut container = full_container(&cfg);
        let q = container.quants.get_mut("layers.0.ffn.w2").unwrap();
        q.a += 1;
        q.z.extend(std::iter::repeat_n(0, q.n));
        q.t.push(1.0);
        assert!(
            PackedWeights::from_container_coded(&cfg, &base, &container, Precision::F64)
                .is_err()
        );
    }

    #[test]
    fn flatten_follows_order() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 3);
        let order = vec!["embed".to_string(), "final_norm".to_string()];
        let flat = w.flatten_f32(&order);
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[0].len(), 128 * 16);
        assert_eq!(flat[1].len(), 16);
    }
}
