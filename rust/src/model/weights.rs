//! Weight storage: named f64 matrices/vectors, loaded from the
//! `artifacts/models/<name>/*.npy` directory written by the build-time
//! trainer, mutated in place by the quantization pipeline.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::container::Container;
use crate::linalg::gemm::{matmul_prepacked, Precision, PrepackedB};
use crate::linalg::Mat;
use crate::util::npy::{Npy, NpyData};

use super::ModelConfig;

/// Named parameters; 2-D ones as `Mat`, 1-D gains as vectors.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub mats: BTreeMap<String, Mat>,
    pub vecs: BTreeMap<String, Vec<f64>>,
}

impl Weights {
    /// Load all `.npy` files of a model directory.
    pub fn load(dir: &Path, cfg: &ModelConfig) -> Result<Weights> {
        let mut w = Weights::default();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
        {
            let path = entry?.path();
            let fname = path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let Some(name) = fname.strip_suffix(".npy") else {
                continue;
            };
            let npy = Npy::read(&path)?;
            let data = match &npy.data {
                NpyData::F32(v) => v.iter().map(|&x| x as f64).collect::<Vec<f64>>(),
                NpyData::I32(_) => bail!("unexpected int weights in {fname}"),
            };
            match npy.shape.len() {
                1 => {
                    w.vecs.insert(name.to_string(), data);
                }
                2 => {
                    w.mats.insert(
                        name.to_string(),
                        Mat::from_vec(npy.shape[0], npy.shape[1], data),
                    );
                }
                d => bail!("{fname}: unsupported rank {d}"),
            }
        }
        w.validate(cfg)?;
        Ok(w)
    }

    /// Structural validation against the config.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in &cfg.quantizable {
            let m = self
                .mats
                .get(name)
                .with_context(|| format!("missing weight {name}"))?;
            let (a, n) = cfg.shape_of(name);
            if (m.rows, m.cols) != (a, n) {
                bail!(
                    "{name}: shape {}x{} != expected {a}x{n}",
                    m.rows,
                    m.cols
                );
            }
        }
        for req in ["embed", "head"] {
            if !self.mats.contains_key(req) {
                bail!("missing weight {req}");
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> &Mat {
        &self.mats[name]
    }

    pub fn get_vec(&self, name: &str) -> &[f64] {
        &self.vecs[name]
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        self.mats.insert(name.to_string(), m);
    }

    /// Random-initialized weights for tests (matches python init scheme).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut w = Weights::default();
        let d = cfg.d_model;
        let names: Vec<String> = {
            let mut v = vec!["embed".to_string(), "head".to_string()];
            for i in 0..cfg.n_layers {
                let p = format!("layers.{i}.");
                for s in ["attn.wq", "attn.wk", "attn.wv", "attn.wo",
                          "ffn.w1", "ffn.w3", "ffn.w2"] {
                    v.push(format!("{p}{s}"));
                }
                w.vecs.insert(format!("{p}norm1"), vec![1.0; d]);
                w.vecs.insert(format!("{p}norm2"), vec![1.0; d]);
            }
            w.vecs.insert("final_norm".to_string(), vec![1.0; d]);
            v
        };
        for name in names {
            let (a, n) = cfg.shape_of(&name);
            let scale = 1.0 / (n.max(1) as f64).sqrt();
            w.mats.insert(
                name,
                Mat::from_fn(a, n, |_, _| scale * rng.gaussian()),
            );
        }
        w
    }

    /// Flattened f32 buffers in `param_order` — the exact argument list
    /// of the AOT forward artifact.
    pub fn flatten_f32(&self, order: &[String]) -> Vec<Vec<f32>> {
        order
            .iter()
            .map(|name| {
                if let Some(m) = self.mats.get(name) {
                    m.to_f32()
                } else {
                    self.vecs[name].iter().map(|&x| x as f32).collect()
                }
            })
            .collect()
    }
}

/// Weights plus per-matrix prepacked projection panels — the serving
/// path's model representation.  Every matrix the forward routes
/// through a projection GEMM (per-layer QKV/wo/FFN and the LM head) is
/// packed **once** at load time via [`PrepackedB::pack_nt`]; batched
/// forwards then skip the per-call B-pack entirely.  The raw f64
/// storage of a packed matrix is dropped right after packing (the
/// packed forward never reads it), so serving holds one copy of each
/// weight, not two; only the embedding table (a row lookup) and the
/// norm gains remain in [`Weights`].
///
/// The pack precision is fixed at build time (normally the
/// `WATERSIC_PRECISION` engine option); a packed forward always runs
/// the blocked driver at that precision, so its outputs are
/// bit-identical across thread counts, batch compositions, and
/// dispatch rungs (see [`PrepackedB`]).
pub struct PackedWeights {
    /// embed + norm gains (+ anything never routed through a
    /// projection); packed matrices are removed from `mats`
    pub weights: Weights,
    pub packed: BTreeMap<String, PrepackedB>,
    pub precision: Precision,
}

impl PackedWeights {
    /// Prepack every projection matrix of `weights` for the given
    /// model architecture.
    pub fn new(
        cfg: &ModelConfig,
        mut weights: Weights,
        prec: Precision,
    ) -> PackedWeights {
        let mut names = vec!["head".to_string()];
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            for s in [
                "attn.wq", "attn.wk", "attn.wv", "attn.wo", "ffn.w1", "ffn.w3",
                "ffn.w2",
            ] {
                names.push(format!("{p}{s}"));
            }
        }
        let mut packed = BTreeMap::new();
        for name in names {
            let pb = PrepackedB::pack_nt(weights.get(&name), prec);
            weights.mats.remove(&name);
            packed.insert(name, pb);
        }
        PackedWeights {
            weights,
            packed,
            precision: prec,
        }
    }

    /// Dequantize a `.wsic` container over the base weights (embed /
    /// norms / head come from `base`; quantized matrices are
    /// reconstructed), then prepack — the container-to-serving load
    /// path.  Quantized matrices are dequantized straight into the
    /// student (the base copies they replace are never cloned), so the
    /// load peak stays near one model's worth of weights.
    pub fn from_container(
        cfg: &ModelConfig,
        base: &Weights,
        container: &Container,
        prec: Precision,
    ) -> Result<PackedWeights> {
        for name in container.quants.keys() {
            if !base.mats.contains_key(name) {
                bail!("container matrix {name} unknown to the base weights");
            }
        }
        let mut student = Weights {
            mats: BTreeMap::new(),
            vecs: base.vecs.clone(),
        };
        for (name, m) in &base.mats {
            let rebuilt = match container.quants.get(name) {
                Some(q) => q.dequant(),
                None => m.clone(),
            };
            student.mats.insert(name.clone(), rebuilt);
        }
        student.validate(cfg)?;
        Ok(Self::new(cfg, student, prec))
    }

    /// Projection through the prepacked panels: x · Wᵀ for the named
    /// matrix, bit-identical to the pack-per-call driver.
    pub fn project(&self, x: &Mat, name: &str) -> Mat {
        matmul_prepacked(x, &self.packed[name])
    }

    /// Total bytes held by the packed panels (load-time telemetry).
    pub fn packed_bytes(&self) -> usize {
        self.packed.values().map(|p| p.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 1);
        w.validate(&cfg).unwrap();
        assert_eq!(w.get("layers.0.ffn.w1").rows, 32);
        assert_eq!(w.get_vec("layers.0.norm1").len(), 16);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 2);
        let dir = std::env::temp_dir().join("wsic_weights_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (name, m) in &w.mats {
            Npy::f32(vec![m.rows, m.cols], m.to_f32())
                .write(&dir.join(format!("{name}.npy")))
                .unwrap();
        }
        for (name, v) in &w.vecs {
            Npy::f32(vec![v.len()], v.iter().map(|&x| x as f32).collect())
                .write(&dir.join(format!("{name}.npy")))
                .unwrap();
        }
        let w2 = Weights::load(&dir, &cfg).unwrap();
        assert_eq!(w.mats.len(), w2.mats.len());
        let a = w.get("layers.0.attn.wq");
        let b = w2.get("layers.0.attn.wq");
        assert!(a.sub(b).max_abs() < 1e-6); // f32 roundtrip tolerance
    }

    #[test]
    fn packed_weights_project_matches_plain() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 11);
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        assert_eq!(pw.packed.len(), 7 * cfg.n_layers + 1);
        assert!(pw.packed_bytes() > 0);
        let mut rng = crate::util::rng::Rng::new(3);
        let x = Mat::from_fn(10, cfg.d_model, |_, _| rng.gaussian());
        let y = pw.project(&x, "layers.0.attn.wq");
        // k = d_model ≤ KC and f64 ⇒ the serial dot of the plain small
        // path reduces in the same order as the single-KC-block packed
        // tile: bitwise equality, not just tolerance
        let y_ref = crate::linalg::gemm::matmul_nt(&x, w.get("layers.0.attn.wq"));
        assert_eq!(y.data, y_ref.data);
    }

    #[test]
    fn flatten_follows_order() {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 3);
        let order = vec!["embed".to_string(), "final_norm".to_string()];
        let flat = w.flatten_f32(&order);
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[0].len(), 128 * 16);
        assert_eq!(flat[1].len(), 16);
    }
}
