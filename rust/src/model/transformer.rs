//! Native forward pass of `picollama` (f64) with calibration capture —
//! the oracle twin of the AOT HLO artifact and the data source for the
//! drift / residual / attention-weighted statistics of §4.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicPtr, Ordering};

use crate::linalg::gemm::{matmul_nt, matmul_nt_prec, Precision};
use crate::linalg::Mat;

use super::weights::{PackedWeights, Weights};
use super::ModelConfig;

/// Calibration capture produced by `forward`.
#[derive(Default, Debug)]
pub struct Capture {
    /// activation panels (tokens × n) keyed by *input group*; see
    /// [`input_group`] for the matrix-name → group mapping.
    pub inputs: BTreeMap<String, Mat>,
    /// residual-stream state (tokens × D) at the point where the named
    /// down-projection (attn.wo / ffn.w2) adds its contribution.
    pub residuals: BTreeMap<String, Mat>,
    /// per-layer attention probabilities, flattened (B, H, T, T).
    pub attn_probs: Vec<Vec<f64>>,
    pub b: usize,
    pub t: usize,
}

/// Which activation panel feeds a given quantizable matrix.
pub fn input_group(matrix: &str) -> String {
    if let Some(pos) = matrix.find("attn.w") {
        let prefix = &matrix[..pos];
        return match &matrix[pos + 6..pos + 7] {
            "o" => format!("{prefix}attn.wo"),
            _ => format!("{prefix}attn.qkv"),
        };
    }
    if let Some(pos) = matrix.find("ffn.w") {
        let prefix = &matrix[..pos];
        return match &matrix[pos + 5..pos + 6] {
            "2" => format!("{prefix}ffn.w2"),
            _ => format!("{prefix}ffn.in"),
        };
    }
    matrix.to_string()
}

/// Intermediates stashed for the reverse pass (WaterSIC-FT).
pub struct Tape {
    pub tokens: Vec<i32>,
    pub x_embed: Mat,
    pub layers: Vec<LayerTape>,
    pub x_final_in: Mat,
    pub x_final: Mat,
    pub logits: Mat,
}

pub struct LayerTape {
    pub x_in: Mat,     // residual entering the block
    pub h1: Mat,       // norm1 output (QKV input)
    pub q: Vec<Mat>,   // per head, post-RoPE (T_total × hd) rows by token
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub probs: Vec<Mat>, // per (batch, head): T×T — index b*H+h
    pub ctxcat: Mat,   // wo input
    pub x_mid: Mat,    // residual after attention
    pub h2: Mat,       // norm2 output (FFN input)
    pub pre1: Mat,     // h2·W1ᵀ (pre-SiLU)
    pub gate: Mat,
    pub up: Mat,
    pub m: Mat,        // gate ⊙ up (w2 input)
}

fn rms_norm(x: &Mat, gain: &[f64], eps: f64) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let ms = row.iter().map(|v| v * v).sum::<f64>() / x.cols as f64;
        let r = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..x.cols {
            orow[j] = row[j] * r * gain[j];
        }
    }
    out
}

fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

pub fn silu_prime(x: f64) -> f64 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// RoPE tables (cos, sin): (T × hd/2), matching the jax implementation.
fn rope_tables(t: usize, hd: usize, theta: f64) -> (Mat, Mat) {
    let half = hd / 2;
    let mut cos = Mat::zeros(t, half);
    let mut sin = Mat::zeros(t, half);
    for p in 0..t {
        for i in 0..half {
            let freq = p as f64 / theta.powf(2.0 * i as f64 / hd as f64);
            cos[(p, i)] = freq.cos();
            sin[(p, i)] = freq.sin();
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to a (tokens × hd) head panel; `pos_of(row)` gives
/// the position of each row within its sequence.
fn apply_rope(x: &mut Mat, cos: &Mat, sin: &Mat, t: usize) {
    let half = x.cols / 2;
    for r in 0..x.rows {
        let p = r % t;
        let row = x.row_mut(r);
        for i in 0..half {
            let (c, s) = (cos[(p, i)], sin[(p, i)]);
            let x1 = row[i];
            let x2 = row[half + i];
            row[i] = x1 * c - x2 * s;
            row[half + i] = x1 * s + x2 * c;
        }
    }
}

/// Reverse of `apply_rope` (rotation transpose) — used by the backward
/// pass.
pub fn apply_rope_backward(g: &mut Mat, cos: &Mat, sin: &Mat, t: usize) {
    let half = g.cols / 2;
    for r in 0..g.rows {
        let p = r % t;
        let row = g.row_mut(r);
        for i in 0..half {
            let (c, s) = (cos[(p, i)], sin[(p, i)]);
            let g1 = row[i];
            let g2 = row[half + i];
            row[i] = g1 * c + g2 * s;
            row[half + i] = -g1 * s + g2 * c;
        }
    }
}

pub struct ForwardOpts {
    pub capture: bool,
    pub tape: bool,
    /// Kernel precision for the projection gemms (QKV, wo, FFN, head).
    /// Attention score/softmax math always runs in f64, and a taped
    /// forward is pinned to f64 (the reverse pass needs the f64
    /// oracle).  The calibration paths thread `WATERSIC_PRECISION`
    /// through here; direct callers default to f64.
    pub precision: Precision,
}

impl Default for ForwardOpts {
    fn default() -> Self {
        ForwardOpts {
            capture: false,
            tape: false,
            precision: Precision::F64,
        }
    }
}

pub struct ForwardOut {
    /// (B·T × V) logits
    pub logits: Mat,
    pub capture: Option<Capture>,
    pub tape: Option<Tape>,
}

/// Where the forward's projection GEMMs read their weights from: the
/// plain per-call-packing path, or the serving path's prepacked panels.
enum WeightSource<'a> {
    Plain(&'a Weights),
    Packed(&'a PackedWeights),
}

impl<'a> WeightSource<'a> {
    fn weights(&self) -> &Weights {
        match self {
            WeightSource::Plain(w) => w,
            WeightSource::Packed(pw) => &pw.weights,
        }
    }

    /// x · Wᵀ for the named projection matrix.  The packed arm always
    /// takes the blocked driver at the pack-time precision (`prec` is
    /// the plain path's knob), which makes every output row's bits
    /// independent of the batch it rides in — the micro-batching
    /// server's parity invariant.
    fn project(&self, x: &Mat, name: &str, prec: Precision) -> Mat {
        match self {
            WeightSource::Plain(w) => matmul_nt_prec(x, w.get(name), prec),
            WeightSource::Packed(pw) => pw.project(x, name),
        }
    }
}

/// Run the model on `tokens` = B windows of length T (flattened row-major).
pub fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    forward_src(cfg, &WeightSource::Plain(w), tokens, b, t, opts)
}

/// [`forward`] through prepacked projection panels — the serving path.
/// Outputs are bit-identical to [`forward`] on the same (b, t) batch
/// whenever the pack-time precision matches the GEMM path `forward`
/// would take, and — unlike the plain path — bit-identical across
/// *different* batch shapes too (see [`PackedWeights`]).  Taping is
/// not supported here: WaterSIC-FT differentiates against the plain
/// f64 oracle.
pub fn forward_packed(
    cfg: &ModelConfig,
    pw: &PackedWeights,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    assert!(!opts.tape, "the packed forward does not tape (serving path)");
    forward_src(cfg, &WeightSource::Packed(pw), tokens, b, t, opts)
}

fn forward_src(
    cfg: &ModelConfig,
    src: &WeightSource,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    let w = src.weights();
    assert_eq!(tokens.len(), b * t);
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let rows = b * t;
    // taped forwards stay f64: the reverse pass differentiates against
    // the f64 oracle (see ForwardOpts::precision)
    let prec = if opts.tape {
        Precision::F64
    } else {
        opts.precision
    };

    let embed = w.get("embed");
    let mut x = Mat::zeros(rows, d);
    for r in 0..rows {
        let tok = tokens[r] as usize;
        x.row_mut(r).copy_from_slice(embed.row(tok));
    }
    let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);

    let mut cap = Capture {
        b,
        t,
        ..Capture::default()
    };
    let mut tapes: Vec<LayerTape> = Vec::new();
    let x_embed = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };

    for li in 0..cfg.n_layers {
        let p = format!("layers.{li}.");
        let x_in = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };

        // ---- attention
        let h1 = rms_norm(&x, w.get_vec(&format!("{p}norm1")), cfg.norm_eps);
        if opts.capture {
            cap.inputs.insert(format!("{p}attn.qkv"), h1.clone());
        }
        let qf = src.project(&h1, &format!("{p}attn.wq"), prec);
        let kf = src.project(&h1, &format!("{p}attn.wk"), prec);
        let vf = src.project(&h1, &format!("{p}attn.wv"), prec);

        // split heads: per head (rows × hd)
        let split = |m: &Mat, h: usize| -> Mat {
            let mut out = Mat::zeros(rows, hd);
            for r in 0..rows {
                out.row_mut(r)
                    .copy_from_slice(&m.row(r)[h * hd..(h + 1) * hd]);
            }
            out
        };
        let mut qs = Vec::with_capacity(nh);
        let mut ks = Vec::with_capacity(nh);
        let mut vs = Vec::with_capacity(nh);
        for h in 0..nh {
            let mut q = split(&qf, h);
            let mut k = split(&kf, h);
            apply_rope(&mut q, &cos, &sin, t);
            apply_rope(&mut k, &cos, &sin, t);
            qs.push(q);
            ks.push(k);
            vs.push(split(&vf, h));
        }

        // attention per (batch, head) — independent tasks, fanned out
        // over the persistent pool and scattered back in (bi, h) order
        // so captures/tapes are identical to the serial sweep
        let pairs: Vec<(usize, usize)> = (0..b)
            .flat_map(|bi| (0..nh).map(move |h| (bi, h)))
            .collect();
        let threads =
            crate::util::threadpool::default_threads().min(pairs.len().max(1));
        // capture probs scatter directly: each (bi, h) task owns the
        // disjoint [(bi·H+h)·t², +t²) slice of probs_flat, so the
        // b·nh t×t blocks are written in place instead of being staged
        // in head_outs and copied (which transiently doubled the
        // capture footprint).  Tape (the rare path) still keeps the
        // per-task Mat; plain inference materializes neither.
        let mut probs_flat: Vec<f64> = if opts.capture {
            vec![0.0; b * nh * t * t]
        } else {
            Vec::new()
        };
        let probs_ptr = AtomicPtr::new(probs_flat.as_mut_ptr());
        let head_outs: Vec<(Mat, Option<Mat>)> =
            crate::util::threadpool::parallel_map(pairs, threads, |(bi, h)| {
                let base = bi * t;
                let q = &qs[h];
                let k = &ks[h];
                let v = &vs[h];
                let mut probs = if opts.tape {
                    Some(Mat::zeros(t, t))
                } else {
                    None
                };
                let flat_base = if opts.capture {
                    // SAFETY: task (bi, h) exclusively owns this t×t
                    // block; probs_flat is not reallocated or read
                    // until every task has completed.
                    Some(unsafe { probs_ptr.load(Ordering::Relaxed).add((bi * nh + h) * t * t) })
                } else {
                    None
                };
                let mut ctx_head = Mat::zeros(t, hd);
                for i in 0..t {
                    let qi = q.row(base + i);
                    // causal scores + online softmax
                    let mut maxs = f64::NEG_INFINITY;
                    let mut srow = vec![0.0; i + 1];
                    for j in 0..=i {
                        let s = crate::linalg::dot(qi, k.row(base + j)) * scale;
                        srow[j] = s;
                        maxs = maxs.max(s);
                    }
                    let mut denom = 0.0;
                    for j in 0..=i {
                        srow[j] = (srow[j] - maxs).exp();
                        denom += srow[j];
                    }
                    // context vector
                    let crow = ctx_head.row_mut(i);
                    for j in 0..=i {
                        let pj = srow[j] / denom;
                        if let Some(pbase) = flat_base {
                            // SAFETY: (i, j) indexes inside this task's
                            // exclusive block.
                            unsafe {
                                *pbase.add(i * t + j) = pj;
                            }
                        }
                        if let Some(p) = probs.as_mut() {
                            p[(i, j)] = pj;
                        }
                        let vrow = v.row(base + j);
                        for e in 0..hd {
                            crow[e] += pj * vrow[e];
                        }
                    }
                }
                (ctx_head, probs)
            });
        let mut ctxcat = Mat::zeros(rows, d);
        let mut probs_store: Vec<Mat> = Vec::new();
        for (idx, (ctx_head, probs)) in head_outs.into_iter().enumerate() {
            let (bi, h) = (idx / nh, idx % nh);
            for i in 0..t {
                ctxcat.row_mut(bi * t + i)[h * hd..(h + 1) * hd]
                    .copy_from_slice(ctx_head.row(i));
            }
            if let Some(p) = probs {
                probs_store.push(p);
            }
        }
        if opts.capture {
            cap.attn_probs.push(probs_flat);
            cap.inputs.insert(format!("{p}attn.wo"), ctxcat.clone());
            cap.residuals.insert(format!("{p}attn.wo"), x.clone());
        }
        let attn_out = src.project(&ctxcat, &format!("{p}attn.wo"), prec);
        let mut x_mid = x.clone();
        for i in 0..rows * d {
            x_mid.data[i] += attn_out.data[i];
        }

        // ---- FFN
        let h2 = rms_norm(&x_mid, w.get_vec(&format!("{p}norm2")), cfg.norm_eps);
        if opts.capture {
            cap.inputs.insert(format!("{p}ffn.in"), h2.clone());
        }
        let pre1 = src.project(&h2, &format!("{p}ffn.w1"), prec);
        let up = src.project(&h2, &format!("{p}ffn.w3"), prec);
        let mut gate = pre1.clone();
        gate.data.iter_mut().for_each(|v| *v = silu(*v));
        let m = gate.hadamard(&up);
        if opts.capture {
            cap.inputs.insert(format!("{p}ffn.w2"), m.clone());
            cap.residuals.insert(format!("{p}ffn.w2"), x_mid.clone());
        }
        let ffn_out = src.project(&m, &format!("{p}ffn.w2"), prec);
        let mut x_out = x_mid.clone();
        for i in 0..rows * d {
            x_out.data[i] += ffn_out.data[i];
        }

        if opts.tape {
            tapes.push(LayerTape {
                x_in,
                h1,
                q: qs,
                k: ks,
                v: vs,
                probs: probs_store,
                ctxcat,
                x_mid,
                h2,
                pre1,
                gate,
                up,
                m,
            });
        }
        x = x_out;
    }

    let x_final_in = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };
    let xf = rms_norm(&x, w.get_vec("final_norm"), cfg.norm_eps);
    let logits = src.project(&xf, "head", prec);

    ForwardOut {
        capture: if opts.capture { Some(cap) } else { None },
        tape: if opts.tape {
            Some(Tape {
                tokens: tokens.to_vec(),
                x_embed,
                layers: tapes,
                x_final_in,
                x_final: xf,
                logits: logits.clone(),
            })
        } else {
            None
        },
        logits,
    }
}

/// Row-wise softmax.
pub fn softmax(logits: &Mat) -> Mat {
    let mut out = logits.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    out
}

/// Mean next-token cross-entropy (nats).  `targets[r]` is the target of
/// logits row r.
pub fn cross_entropy(logits: &Mat, targets: &[i32]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        total += lse - row[targets[i] as usize];
    }
    total / logits.rows as f64
}

/// KL(P‖Q) per token between two logit matrices (nats).
pub fn kl_divergence(p_logits: &Mat, q_logits: &Mat) -> f64 {
    assert_eq!(p_logits.rows, q_logits.rows);
    let p = softmax(p_logits);
    let mut total = 0.0;
    for i in 0..p.rows {
        let prow = p.row(i);
        let ql = q_logits.row(i);
        let mx = ql.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + ql.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        let pl = p_logits.row(i);
        let mxp = pl.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lsep = mxp + pl.iter().map(|v| (v - mxp).exp()).sum::<f64>().ln();
        for j in 0..p.cols {
            if prow[j] > 0.0 {
                total += prow[j] * ((pl[j] - lsep) - (ql[j] - lse));
            }
        }
    }
    total / p.rows as f64
}

/// Attention output given candidate QKV weights on a given input panel —
/// the objective evaluator of eq. (60).  `h1` is the (tokens × D) QKV
/// input panel, laid out as b windows of t tokens.
pub fn attention_block_output(
    cfg: &ModelConfig,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    h1: &Mat,
    b: usize,
    t: usize,
) -> Mat {
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let rows = b * t;
    assert_eq!(h1.rows, rows);
    let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);
    let qf = matmul_nt(h1, wq);
    let kf = matmul_nt(h1, wk);
    let vf = matmul_nt(h1, wv);
    // heads are independent — evaluate them across the persistent pool
    // (this sits inside the eq. 60 mixing objective, which is called
    // once per candidate (ε_qr, ε_aw) point)
    let threads = crate::util::threadpool::default_threads().min(nh.max(1));
    let heads: Vec<usize> = (0..nh).collect();
    let head_outs: Vec<Mat> = crate::util::threadpool::parallel_map(
        heads,
        threads,
        |h| {
            let mut q = Mat::zeros(rows, hd);
            let mut k = Mat::zeros(rows, hd);
            let mut v = Mat::zeros(rows, hd);
            for r in 0..rows {
                q.row_mut(r).copy_from_slice(&qf.row(r)[h * hd..(h + 1) * hd]);
                k.row_mut(r).copy_from_slice(&kf.row(r)[h * hd..(h + 1) * hd]);
                v.row_mut(r).copy_from_slice(&vf.row(r)[h * hd..(h + 1) * hd]);
            }
            apply_rope(&mut q, &cos, &sin, t);
            apply_rope(&mut k, &cos, &sin, t);
            let mut ctx_head = Mat::zeros(rows, hd);
            for bi in 0..b {
                let base = bi * t;
                for i in 0..t {
                    let qi = q.row(base + i);
                    let mut maxs = f64::NEG_INFINITY;
                    let mut srow = vec![0.0; i + 1];
                    for j in 0..=i {
                        let s = crate::linalg::dot(qi, k.row(base + j)) * scale;
                        srow[j] = s;
                        maxs = maxs.max(s);
                    }
                    let mut denom = 0.0;
                    for j in 0..=i {
                        srow[j] = (srow[j] - maxs).exp();
                        denom += srow[j];
                    }
                    let orow = ctx_head.row_mut(base + i);
                    for j in 0..=i {
                        let pj = srow[j] / denom;
                        let vrow = v.row(base + j);
                        for e in 0..hd {
                            orow[e] += pj * vrow[e];
                        }
                    }
                }
            }
            ctx_head
        },
    );
    let mut out = Mat::zeros(rows, d);
    for (h, ctx_head) in head_outs.iter().enumerate() {
        for r in 0..rows {
            out.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(ctx_head.row(r));
        }
    }
    out
}

/// Greedy sample continuation (used by the quickstart example).
pub fn greedy_continuation(
    cfg: &ModelConfig,
    w: &Weights,
    prompt: &[i32],
    steps: usize,
) -> Vec<i32> {
    let mut toks = prompt.to_vec();
    for _ in 0..steps {
        let t = toks.len().min(cfg.ctx);
        let window = &toks[toks.len() - t..];
        let out = forward(cfg, w, window, 1, t, &ForwardOpts::default());
        let last = out.logits.row(t - 1);
        let arg = (0..cfg.vocab)
            .max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap())
            .unwrap();
        toks.push(arg as i32);
    }
    toks
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (ModelConfig, Weights, Vec<i32>) {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 5);
        let mut rng = Rng::new(9);
        let tokens: Vec<i32> = (0..2 * cfg.ctx)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        (cfg, w, tokens)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let (cfg, w, tokens) = setup();
        let out = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert_eq!(out.logits.rows, 2 * cfg.ctx);
        assert_eq!(out.logits.cols, cfg.vocab);
        assert!(out.logits.is_finite());
    }

    #[test]
    fn capture_panels_have_expected_shapes() {
        let (cfg, w, tokens) = setup();
        let out = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                capture: true,
                tape: false,
                ..ForwardOpts::default()
            },
        );
        let cap = out.capture.unwrap();
        let rows = 2 * cfg.ctx;
        assert_eq!(cap.inputs["layers.0.attn.qkv"].rows, rows);
        assert_eq!(cap.inputs["layers.0.attn.wo"].cols, cfg.d_model);
        assert_eq!(cap.inputs["layers.0.ffn.in"].cols, cfg.d_model);
        assert_eq!(cap.inputs["layers.0.ffn.w2"].cols, cfg.d_ff);
        assert_eq!(cap.residuals["layers.0.ffn.w2"].rows, rows);
        assert_eq!(
            cap.attn_probs[0].len(),
            2 * cfg.n_heads * cfg.ctx * cfg.ctx
        );
        // attention rows sum to 1 (causal softmax)
        let t = cfg.ctx;
        let probs = &cap.attn_probs[0];
        for i in 0..t {
            let row_sum: f64 = (0..t).map(|j| probs[i * t + j]).sum();
            assert!((row_sum - 1.0).abs() < 1e-9, "row {i}: {row_sum}");
        }
    }

    #[test]
    fn input_group_mapping() {
        assert_eq!(input_group("layers.3.attn.wq"), "layers.3.attn.qkv");
        assert_eq!(input_group("layers.3.attn.wv"), "layers.3.attn.qkv");
        assert_eq!(input_group("layers.3.attn.wo"), "layers.3.attn.wo");
        assert_eq!(input_group("layers.0.ffn.w1"), "layers.0.ffn.in");
        assert_eq!(input_group("layers.0.ffn.w2"), "layers.0.ffn.w2");
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let logits = Mat::zeros(5, 64);
        let ce = cross_entropy(&logits, &[0, 1, 2, 3, 4]);
        assert!((ce - (64f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn kl_zero_for_identical() {
        let (cfg, w, tokens) = setup();
        let out = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert!(kl_divergence(&out.logits, &out.logits).abs() < 1e-12);
        // and positive for different models
        let w2 = Weights::random(&cfg, 17);
        let out2 = forward(&cfg, &w2, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert!(kl_divergence(&out.logits, &out2.logits) > 0.0);
    }

    #[test]
    fn attention_block_output_matches_forward_capture() {
        let (cfg, w, tokens) = setup();
        let out = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                capture: true,
                tape: false,
                ..ForwardOpts::default()
            },
        );
        let cap = out.capture.unwrap();
        let h1 = &cap.inputs["layers.0.attn.qkv"];
        let ctx = attention_block_output(
            &cfg,
            w.get("layers.0.attn.wq"),
            w.get("layers.0.attn.wk"),
            w.get("layers.0.attn.wv"),
            h1,
            2,
            cfg.ctx,
        );
        let expect = &cap.inputs["layers.0.attn.wo"];
        assert!(ctx.sub(expect).max_abs() < 1e-9);
    }

    #[test]
    fn rope_backward_is_inverse_rotation() {
        let cfg = ModelConfig::tiny_test();
        let hd = cfg.head_dim();
        let (cos, sin) = rope_tables(6, hd, cfg.rope_theta);
        let mut rng = Rng::new(2);
        let orig = Mat::from_fn(6, hd, |_, _| rng.gaussian());
        let mut x = orig.clone();
        apply_rope(&mut x, &cos, &sin, 6);
        apply_rope_backward(&mut x, &cos, &sin, 6);
        assert!(x.sub(&orig).max_abs() < 1e-12);
    }

    #[test]
    fn f32_forward_close_to_f64() {
        // a config wide enough that the projection gemms clear the
        // packed-path threshold, so f32 mode actually engages
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            ctx: 64,
            ..ModelConfig::tiny_test()
        };
        let w = Weights::random(&cfg, 7);
        let mut rng = Rng::new(13);
        let tokens: Vec<i32> = (0..2 * cfg.ctx)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let o64 = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let o32 = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                precision: Precision::F32,
                ..ForwardOpts::default()
            },
        );
        let rel = o32.logits.sub(&o64.logits).frob_norm()
            / o64.logits.frob_norm().max(1e-30);
        assert!(rel > 0.0, "f32 path did not engage");
        assert!(rel < 1e-4, "f32 forward drifted: {rel}");
    }

    #[test]
    fn packed_forward_bit_identical_to_plain_f64() {
        // tiny-model projections either sit below the packed threshold
        // (k ≤ KC ⇒ the serial dot reduces in the same order as the
        // single-KC-block packed tile) or route through the very same
        // driver — so plain and packed forwards must agree bit for bit
        let (cfg, w, tokens) = setup();
        let plain = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        let packed =
            forward_packed(&cfg, &pw, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert_eq!(plain.logits.data, packed.logits.data);
    }

    #[test]
    fn packed_forward_f32_close_to_f64() {
        let (cfg, w, tokens) = setup();
        let plain = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let pw32 = PackedWeights::new(&cfg, w.clone(), Precision::F32);
        let packed =
            forward_packed(&cfg, &pw32, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let rel = packed.logits.sub(&plain.logits).frob_norm()
            / plain.logits.frob_norm().max(1e-30);
        assert!(rel < 1e-4, "f32 packed forward drifted: {rel}");
    }

    #[test]
    fn greedy_continuation_extends() {
        let (cfg, w, tokens) = setup();
        let out = greedy_continuation(&cfg, &w, &tokens[..4], 3);
        assert_eq!(out.len(), 7);
        assert!(out.iter().all(|&t| (t as usize) < cfg.vocab));
    }
}
