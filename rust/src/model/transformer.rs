//! Native forward pass of `picollama` (f64) with calibration capture —
//! the oracle twin of the AOT HLO artifact and the data source for the
//! drift / residual / attention-weighted statistics of §4.
//!
//! # Incremental decode
//!
//! Generation runs through a per-sequence [`KvCache`]: a prefill
//! forward ([`prefill`] / [`prefill_packed`]) stashes every layer's
//! post-RoPE K and V rows, and each subsequent [`decode_step`] /
//! [`decode_packed`] computes only the new token's projections and
//! attends against the cached rows — O(t) per token instead of the
//! O(t²) full re-score.  The cached step is **bit-identical** (f64) to
//! the last row of a full-window forward, because every piece of the
//! computation is exactly the suffix of the full pass:
//!
//! * RoPE entry (p, i) depends only on the position p — not on the
//!   table length — so rotating the new token at position `len` matches
//!   the full forward's rotation of its last row;
//! * attention row i reduces scores j = 0..=i with a sequential online
//!   softmax; the decode step reproduces row i = t−1's reduction order
//!   exactly;
//! * all other ops (rms_norm, residuals, FFN, head) are row-local, and
//!   the prepacked GEMM driver's row independence makes a 1-row decode
//!   projection bit-identical to the same row inside a full window.
//!
//! The one case the cache cannot serve is a *slid* window: once a
//! sequence exceeds `cfg.ctx`, every cached position shifts and the
//! window must be re-prefilled (matching the windowed re-score
//! semantics of the old loop bit for bit).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicPtr, Ordering};

use crate::linalg::gemm::{matmul_nt, matmul_nt_prec, Precision};
use crate::linalg::Mat;

use super::weights::{PackedWeights, Weights};
use super::ModelConfig;

/// Calibration capture produced by `forward`.
#[derive(Default, Debug)]
pub struct Capture {
    /// activation panels (tokens × n) keyed by *input group*; see
    /// [`input_group`] for the matrix-name → group mapping.
    pub inputs: BTreeMap<String, Mat>,
    /// residual-stream state (tokens × D) at the point where the named
    /// down-projection (attn.wo / ffn.w2) adds its contribution.
    pub residuals: BTreeMap<String, Mat>,
    /// per-layer attention probabilities, flattened (B, H, T, T).
    pub attn_probs: Vec<Vec<f64>>,
    pub b: usize,
    pub t: usize,
}

/// Which activation panel feeds a given quantizable matrix.
pub fn input_group(matrix: &str) -> String {
    if let Some(pos) = matrix.find("attn.w") {
        let prefix = &matrix[..pos];
        return match &matrix[pos + 6..pos + 7] {
            "o" => format!("{prefix}attn.wo"),
            _ => format!("{prefix}attn.qkv"),
        };
    }
    if let Some(pos) = matrix.find("ffn.w") {
        let prefix = &matrix[..pos];
        return match &matrix[pos + 5..pos + 6] {
            "2" => format!("{prefix}ffn.w2"),
            _ => format!("{prefix}ffn.in"),
        };
    }
    matrix.to_string()
}

/// Intermediates stashed for the reverse pass (WaterSIC-FT).
pub struct Tape {
    pub tokens: Vec<i32>,
    pub x_embed: Mat,
    pub layers: Vec<LayerTape>,
    pub x_final_in: Mat,
    pub x_final: Mat,
    pub logits: Mat,
}

pub struct LayerTape {
    pub x_in: Mat,     // residual entering the block
    pub h1: Mat,       // norm1 output (QKV input)
    pub q: Vec<Mat>,   // per head, post-RoPE (T_total × hd) rows by token
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub probs: Vec<Mat>, // per (batch, head): T×T — index b*H+h
    pub ctxcat: Mat,   // wo input
    pub x_mid: Mat,    // residual after attention
    pub h2: Mat,       // norm2 output (FFN input)
    pub pre1: Mat,     // h2·W1ᵀ (pre-SiLU)
    pub gate: Mat,
    pub up: Mat,
    pub m: Mat,        // gate ⊙ up (w2 input)
}

fn rms_norm(x: &Mat, gain: &[f64], eps: f64) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let ms = row.iter().map(|v| v * v).sum::<f64>() / x.cols as f64;
        let r = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..x.cols {
            orow[j] = row[j] * r * gain[j];
        }
    }
    out
}

fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

pub fn silu_prime(x: f64) -> f64 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// RoPE tables (cos, sin): (T × hd/2), matching the jax implementation.
fn rope_tables(t: usize, hd: usize, theta: f64) -> (Mat, Mat) {
    let half = hd / 2;
    let mut cos = Mat::zeros(t, half);
    let mut sin = Mat::zeros(t, half);
    for p in 0..t {
        for i in 0..half {
            let freq = p as f64 / theta.powf(2.0 * i as f64 / hd as f64);
            cos[(p, i)] = freq.cos();
            sin[(p, i)] = freq.sin();
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to a (tokens × hd) head panel; `pos_of(row)` gives
/// the position of each row within its sequence.
fn apply_rope(x: &mut Mat, cos: &Mat, sin: &Mat, t: usize) {
    let half = x.cols / 2;
    for r in 0..x.rows {
        let p = r % t;
        let row = x.row_mut(r);
        for i in 0..half {
            let (c, s) = (cos[(p, i)], sin[(p, i)]);
            let x1 = row[i];
            let x2 = row[half + i];
            row[i] = x1 * c - x2 * s;
            row[half + i] = x1 * s + x2 * c;
        }
    }
}

/// Reverse of `apply_rope` (rotation transpose) — used by the backward
/// pass.
pub fn apply_rope_backward(g: &mut Mat, cos: &Mat, sin: &Mat, t: usize) {
    let half = g.cols / 2;
    for r in 0..g.rows {
        let p = r % t;
        let row = g.row_mut(r);
        for i in 0..half {
            let (c, s) = (cos[(p, i)], sin[(p, i)]);
            let g1 = row[i];
            let g2 = row[half + i];
            row[i] = g1 * c + g2 * s;
            row[half + i] = -g1 * s + g2 * c;
        }
    }
}

pub struct ForwardOpts {
    pub capture: bool,
    pub tape: bool,
    /// Kernel precision for the projection gemms (QKV, wo, FFN, head).
    /// Attention score/softmax math always runs in f64, and a taped
    /// forward is pinned to f64 (the reverse pass needs the f64
    /// oracle).  The calibration paths thread `WATERSIC_PRECISION`
    /// through here; direct callers default to f64.
    pub precision: Precision,
}

impl Default for ForwardOpts {
    fn default() -> Self {
        ForwardOpts {
            capture: false,
            tape: false,
            precision: Precision::F64,
        }
    }
}

pub struct ForwardOut {
    /// (B·T × V) logits
    pub logits: Mat,
    pub capture: Option<Capture>,
    pub tape: Option<Tape>,
}

/// Where the forward's projection GEMMs read their weights from: the
/// plain per-call-packing path, or the serving path's packed
/// projections.  The packed arm is itself two resident forms behind
/// one seam — eager dequantized panels or bit-packed quantized codes
/// decoded inside the pack stage
/// ([`crate::model::weights::PackedProjection`], selected by the
/// `WATERSIC_SERVE_WEIGHTS` engine option at load) — which project
/// bit-identically, so nothing above this enum can observe the
/// residency mode.
enum WeightSource<'a> {
    Plain(&'a Weights),
    Packed(&'a PackedWeights),
}

impl<'a> WeightSource<'a> {
    fn weights(&self) -> &Weights {
        match self {
            WeightSource::Plain(w) => w,
            WeightSource::Packed(pw) => &pw.weights,
        }
    }

    /// x · Wᵀ for the named projection matrix.  The packed arm always
    /// takes the blocked driver at the pack-time precision (`prec` is
    /// the plain path's knob), which makes every output row's bits
    /// independent of the batch it rides in — the micro-batching
    /// server's parity invariant.
    fn project(&self, x: &Mat, name: &str, prec: Precision) -> Mat {
        match self {
            WeightSource::Plain(w) => matmul_nt_prec(x, w.get(name), prec),
            WeightSource::Packed(pw) => pw.project(x, name),
        }
    }

    /// The layer's Q/K/V projections.  The packed arm runs one fused
    /// GEMM against the `attn.qkv` panels (decode-shaped: one driver
    /// dispatch instead of three); per-column reduction independence
    /// keeps each split output bit-identical to the separate products
    /// the plain arm computes.
    fn project_qkv(
        &self,
        x: &Mat,
        layer_prefix: &str,
        prec: Precision,
    ) -> (Mat, Mat, Mat) {
        match self {
            WeightSource::Plain(w) => (
                matmul_nt_prec(x, w.get(&format!("{layer_prefix}attn.wq")), prec),
                matmul_nt_prec(x, w.get(&format!("{layer_prefix}attn.wk")), prec),
                matmul_nt_prec(x, w.get(&format!("{layer_prefix}attn.wv")), prec),
            ),
            WeightSource::Packed(pw) => pw.project_qkv(x, layer_prefix),
        }
    }

    /// The layer's FFN input projections (w1, w3) — fused on the packed
    /// arm, separate products on the plain arm.
    fn project_ffn_in(
        &self,
        x: &Mat,
        layer_prefix: &str,
        prec: Precision,
    ) -> (Mat, Mat) {
        match self {
            WeightSource::Plain(w) => (
                matmul_nt_prec(x, w.get(&format!("{layer_prefix}ffn.w1")), prec),
                matmul_nt_prec(x, w.get(&format!("{layer_prefix}ffn.w3")), prec),
            ),
            WeightSource::Packed(pw) => pw.project_ffn_in(x, layer_prefix),
        }
    }
}

/// Per-sequence decode state: every layer's post-RoPE K and V rows for
/// the positions evaluated so far, plus the RoPE tables for the full
/// capacity (precomputed once — entry (p, i) is position-local, so the
/// table is identical to the one a full forward of any window length
/// ≥ p+1 would build).  Storage is allocated up front at `cap`
/// positions; [`KvCache::bytes_for`] is the admission-control estimate
/// the serving engine budgets with.
pub struct KvCache {
    /// per (layer, head) — indexed `li * n_heads + h` — each cap × hd
    k: Vec<Mat>,
    v: Vec<Mat>,
    cos: Mat,
    sin: Mat,
    len: usize,
    cap: usize,
    layers: usize,
    nh: usize,
    hd: usize,
}

impl KvCache {
    /// Allocate a cache for up to `cap` positions (`cap` may be below
    /// `cfg.ctx` when the sequence's window can never grow that far —
    /// the serving engine sizes caches at `min(ctx, window + steps − 1)`).
    pub fn new(cfg: &ModelConfig, cap: usize) -> KvCache {
        assert!(cap <= cfg.ctx, "kv capacity {cap} exceeds ctx {}", cfg.ctx);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let slots = cfg.n_layers * nh;
        let k = (0..slots).map(|_| Mat::zeros(cap, hd)).collect();
        let v = (0..slots).map(|_| Mat::zeros(cap, hd)).collect();
        let (cos, sin) = rope_tables(cap, hd, cfg.rope_theta);
        KvCache {
            k,
            v,
            cos,
            sin,
            len: 0,
            cap,
            layers: cfg.n_layers,
            nh,
            hd,
        }
    }

    /// Positions currently cached (the next decode evaluates this
    /// position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// A full cache means the window has saturated: the next token
    /// slides the window, invalidating every cached position — the
    /// caller must [`KvCache::clear`] and re-prefill the slid window.
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Bytes this cache holds (K/V panels + RoPE tables).
    pub fn bytes(&self) -> usize {
        Self::bytes_for_dims(self.layers, self.nh, self.hd, self.cap)
    }

    /// Bytes a cache of `cap` positions costs for this architecture —
    /// the serving engine's `WATERSIC_SERVE_KV_BUDGET` admission
    /// estimate.
    pub fn bytes_for(cfg: &ModelConfig, cap: usize) -> usize {
        Self::bytes_for_dims(cfg.n_layers, cfg.n_heads, cfg.head_dim(), cap)
    }

    fn bytes_for_dims(layers: usize, nh: usize, hd: usize, cap: usize) -> usize {
        // K + V: layers·nh panels of cap×hd each, twice; RoPE cos+sin:
        // 2 · cap × hd/2
        (layers * nh * 2 * cap * hd + cap * hd) * std::mem::size_of::<f64>()
    }

    fn check(&self, cfg: &ModelConfig) {
        assert_eq!(
            (self.layers, self.nh, self.hd),
            (cfg.n_layers, cfg.n_heads, cfg.head_dim()),
            "kv cache was built for a different architecture"
        );
    }
}

/// RoPE-rotate one head row at position `p` (the single-row twin of
/// [`apply_rope`] — identical arithmetic, so identical bits).
fn rope_rotate_row(row: &mut [f64], cos: &Mat, sin: &Mat, p: usize) {
    let half = row.len() / 2;
    for i in 0..half {
        let (c, s) = (cos[(p, i)], sin[(p, i)]);
        let x1 = row[i];
        let x2 = row[half + i];
        row[i] = x1 * c - x2 * s;
        row[half + i] = x1 * s + x2 * c;
    }
}

/// Index of the maximal logit, ties broken toward the **last** maximum
/// — the greedy-sampling rule every decode path in the repo shares
/// (it matches `Iterator::max_by`, which returns the last max).
pub fn argmax_last(row: &[f64]) -> usize {
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0;
    for (i, &v) in row.iter().enumerate() {
        if v >= best {
            best = v;
            arg = i;
        }
    }
    arg
}

/// Run the model on `tokens` = B windows of length T (flattened row-major).
pub fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    forward_src(cfg, &WeightSource::Plain(w), tokens, b, t, opts)
}

/// [`forward`] through prepacked projection panels — the serving path.
/// Outputs are bit-identical to [`forward`] on the same (b, t) batch
/// whenever the pack-time precision matches the GEMM path `forward`
/// would take, and — unlike the plain path — bit-identical across
/// *different* batch shapes too (see [`PackedWeights`]).  Taping is
/// not supported here: WaterSIC-FT differentiates against the plain
/// f64 oracle.
pub fn forward_packed(
    cfg: &ModelConfig,
    pw: &PackedWeights,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    assert!(!opts.tape, "the packed forward does not tape (serving path)");
    forward_src(cfg, &WeightSource::Packed(pw), tokens, b, t, opts)
}

fn forward_src(
    cfg: &ModelConfig,
    src: &WeightSource,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
) -> ForwardOut {
    forward_src_kv(cfg, src, tokens, b, t, opts, &mut [])
}

/// [`forward_src`] with optional per-window KV sinks: `kv[bi]`, when
/// `Some((cache, real_len))`, receives the post-RoPE K/V rows of window
/// `bi`'s first `real_len` tokens (rows past `real_len` are padding the
/// batcher added) and has its length set to `real_len` — the prefill
/// half of the incremental-decode contract.  An empty slice captures
/// nothing.
fn forward_src_kv(
    cfg: &ModelConfig,
    src: &WeightSource,
    tokens: &[i32],
    b: usize,
    t: usize,
    opts: &ForwardOpts,
    kv: &mut [Option<(&mut KvCache, usize)>],
) -> ForwardOut {
    let w = src.weights();
    assert_eq!(tokens.len(), b * t);
    assert!(
        kv.is_empty() || kv.len() == b,
        "kv sinks: expected one slot per window ({b}), got {}",
        kv.len()
    );
    for slot in kv.iter() {
        if let Some((cache, real_len)) = slot {
            cache.check(cfg);
            assert!(
                *real_len >= 1 && *real_len <= t,
                "kv sink real_len {real_len} outside 1..={t}"
            );
            assert!(
                *real_len <= cache.cap,
                "kv sink real_len {real_len} exceeds cache capacity {}",
                cache.cap
            );
        }
    }
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let rows = b * t;
    // taped forwards stay f64: the reverse pass differentiates against
    // the f64 oracle (see ForwardOpts::precision)
    let prec = if opts.tape {
        Precision::F64
    } else {
        opts.precision
    };

    let embed = w.get("embed");
    let mut x = Mat::zeros(rows, d);
    for r in 0..rows {
        let tok = tokens[r] as usize;
        x.row_mut(r).copy_from_slice(embed.row(tok));
    }
    let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);

    let mut cap = Capture {
        b,
        t,
        ..Capture::default()
    };
    let mut tapes: Vec<LayerTape> = Vec::new();
    let x_embed = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };

    for li in 0..cfg.n_layers {
        let p = format!("layers.{li}.");
        let x_in = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };

        // ---- attention
        let h1 = rms_norm(&x, w.get_vec(&format!("{p}norm1")), cfg.norm_eps);
        if opts.capture {
            cap.inputs.insert(format!("{p}attn.qkv"), h1.clone());
        }
        let (qf, kf, vf) = src.project_qkv(&h1, &p, prec);

        // split heads: per head (rows × hd)
        let split = |m: &Mat, h: usize| -> Mat {
            let mut out = Mat::zeros(rows, hd);
            for r in 0..rows {
                out.row_mut(r)
                    .copy_from_slice(&m.row(r)[h * hd..(h + 1) * hd]);
            }
            out
        };
        let mut qs = Vec::with_capacity(nh);
        let mut ks = Vec::with_capacity(nh);
        let mut vs = Vec::with_capacity(nh);
        for h in 0..nh {
            let mut q = split(&qf, h);
            let mut k = split(&kf, h);
            apply_rope(&mut q, &cos, &sin, t);
            apply_rope(&mut k, &cos, &sin, t);
            qs.push(q);
            ks.push(k);
            vs.push(split(&vf, h));
        }

        // stash this layer's post-RoPE K/V rows for windows that carry
        // a decode cache (rows past real_len are batch padding)
        for (bi, slot) in kv.iter_mut().enumerate() {
            if let Some((cache, real_len)) = slot {
                let base = bi * t;
                for h in 0..nh {
                    let si = li * nh + h;
                    for i in 0..*real_len {
                        cache.k[si].row_mut(i).copy_from_slice(ks[h].row(base + i));
                        cache.v[si].row_mut(i).copy_from_slice(vs[h].row(base + i));
                    }
                }
            }
        }

        // attention per (batch, head) — independent tasks, fanned out
        // over the persistent pool and scattered back in (bi, h) order
        // so captures/tapes are identical to the serial sweep
        let pairs: Vec<(usize, usize)> = (0..b)
            .flat_map(|bi| (0..nh).map(move |h| (bi, h)))
            .collect();
        let threads =
            crate::util::threadpool::default_threads().min(pairs.len().max(1));
        // capture probs scatter directly: each (bi, h) task owns the
        // disjoint [(bi·H+h)·t², +t²) slice of probs_flat, so the
        // b·nh t×t blocks are written in place instead of being staged
        // in head_outs and copied (which transiently doubled the
        // capture footprint).  Tape (the rare path) still keeps the
        // per-task Mat; plain inference materializes neither.
        let mut probs_flat: Vec<f64> = if opts.capture {
            vec![0.0; b * nh * t * t]
        } else {
            Vec::new()
        };
        let probs_ptr = AtomicPtr::new(probs_flat.as_mut_ptr());
        let head_outs: Vec<(Mat, Option<Mat>)> =
            crate::util::threadpool::parallel_map(pairs, threads, |(bi, h)| {
                let base = bi * t;
                let q = &qs[h];
                let k = &ks[h];
                let v = &vs[h];
                let mut probs = if opts.tape {
                    Some(Mat::zeros(t, t))
                } else {
                    None
                };
                let flat_base = if opts.capture {
                    // SAFETY: task (bi, h) exclusively owns this t×t
                    // block; probs_flat is not reallocated or read
                    // until every task has completed.
                    Some(unsafe { probs_ptr.load(Ordering::Relaxed).add((bi * nh + h) * t * t) })
                } else {
                    None
                };
                if let Some(pbase) = flat_base {
                    // check-aliasing: the t×t prob block of (bi, h) is
                    // this task's exclusive write-set
                    crate::util::aliasing::claim(pbase as *const f64, t * t);
                }
                let mut ctx_head = Mat::zeros(t, hd);
                for i in 0..t {
                    let qi = q.row(base + i);
                    // causal scores + online softmax
                    let mut maxs = f64::NEG_INFINITY;
                    let mut srow = vec![0.0; i + 1];
                    for j in 0..=i {
                        let s = crate::linalg::dot(qi, k.row(base + j)) * scale;
                        srow[j] = s;
                        maxs = maxs.max(s);
                    }
                    let mut denom = 0.0;
                    for j in 0..=i {
                        srow[j] = (srow[j] - maxs).exp();
                        denom += srow[j];
                    }
                    // context vector
                    let crow = ctx_head.row_mut(i);
                    for j in 0..=i {
                        let pj = srow[j] / denom;
                        if let Some(pbase) = flat_base {
                            // SAFETY: (i, j) indexes inside this task's
                            // exclusive block.
                            unsafe {
                                *pbase.add(i * t + j) = pj;
                            }
                        }
                        if let Some(p) = probs.as_mut() {
                            p[(i, j)] = pj;
                        }
                        let vrow = v.row(base + j);
                        for e in 0..hd {
                            crow[e] += pj * vrow[e];
                        }
                    }
                }
                (ctx_head, probs)
            });
        let mut ctxcat = Mat::zeros(rows, d);
        let mut probs_store: Vec<Mat> = Vec::new();
        for (idx, (ctx_head, probs)) in head_outs.into_iter().enumerate() {
            let (bi, h) = (idx / nh, idx % nh);
            for i in 0..t {
                ctxcat.row_mut(bi * t + i)[h * hd..(h + 1) * hd]
                    .copy_from_slice(ctx_head.row(i));
            }
            if let Some(p) = probs {
                probs_store.push(p);
            }
        }
        if opts.capture {
            cap.attn_probs.push(probs_flat);
            cap.inputs.insert(format!("{p}attn.wo"), ctxcat.clone());
            cap.residuals.insert(format!("{p}attn.wo"), x.clone());
        }
        let attn_out = src.project(&ctxcat, &format!("{p}attn.wo"), prec);
        let mut x_mid = x.clone();
        for i in 0..rows * d {
            x_mid.data[i] += attn_out.data[i];
        }

        // ---- FFN
        let h2 = rms_norm(&x_mid, w.get_vec(&format!("{p}norm2")), cfg.norm_eps);
        if opts.capture {
            cap.inputs.insert(format!("{p}ffn.in"), h2.clone());
        }
        let (pre1, up) = src.project_ffn_in(&h2, &p, prec);
        let mut gate = pre1.clone();
        gate.data.iter_mut().for_each(|v| *v = silu(*v));
        let m = gate.hadamard(&up);
        if opts.capture {
            cap.inputs.insert(format!("{p}ffn.w2"), m.clone());
            cap.residuals.insert(format!("{p}ffn.w2"), x_mid.clone());
        }
        let ffn_out = src.project(&m, &format!("{p}ffn.w2"), prec);
        let mut x_out = x_mid.clone();
        for i in 0..rows * d {
            x_out.data[i] += ffn_out.data[i];
        }

        if opts.tape {
            tapes.push(LayerTape {
                x_in,
                h1,
                q: qs,
                k: ks,
                v: vs,
                probs: probs_store,
                ctxcat,
                x_mid,
                h2,
                pre1,
                gate,
                up,
                m,
            });
        }
        x = x_out;
    }

    for slot in kv.iter_mut() {
        if let Some((cache, real_len)) = slot {
            cache.len = *real_len;
        }
    }

    let x_final_in = if opts.tape { x.clone() } else { Mat::zeros(0, 0) };
    let xf = rms_norm(&x, w.get_vec("final_norm"), cfg.norm_eps);
    let logits = src.project(&xf, "head", prec);

    ForwardOut {
        capture: if opts.capture { Some(cap) } else { None },
        tape: if opts.tape {
            Some(Tape {
                tokens: tokens.to_vec(),
                x_embed,
                layers: tapes,
                x_final_in,
                x_final: xf,
                logits: logits.clone(),
            })
        } else {
            None
        },
        logits,
    }
}

/// Full forward over `b` windows that also fills each window's
/// [`KvCache`] — the batched prefill of the serving engine.  Logits
/// are bit-identical to [`forward_packed`] (the sink writes are pure
/// copies).  `kv[bi] = Some((cache, real_len))` caches window `bi`'s
/// first `real_len` rows; `None` skips that window (a score request
/// riding the same prefill batch).
pub fn prefill_packed(
    cfg: &ModelConfig,
    pw: &PackedWeights,
    tokens: &[i32],
    b: usize,
    t: usize,
    kv: &mut [Option<(&mut KvCache, usize)>],
    opts: &ForwardOpts,
) -> ForwardOut {
    assert!(!opts.tape, "the packed forward does not tape (serving path)");
    forward_src_kv(cfg, &WeightSource::Packed(pw), tokens, b, t, opts, kv)
}

/// Single-window plain-weights prefill (the offline greedy path).
pub fn prefill(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[i32],
    cache: &mut KvCache,
) -> ForwardOut {
    let t = tokens.len();
    let mut kv = [Some((cache, t))];
    forward_src_kv(
        cfg,
        &WeightSource::Plain(w),
        tokens,
        1,
        t,
        &ForwardOpts::default(),
        &mut kv,
    )
}

/// One incremental decode step for a batch of sequences: `tokens[s]`
/// is sequence `s`'s next input token, evaluated at position
/// `caches[s].len()` against that sequence's cached K/V.  Returns the
/// (b × vocab) next-token logits and advances every cache by one
/// position.  Bit-identical (f64) to the last logits row of a full
/// forward over the sequence's whole window — see the module docs for
/// the argument.
fn decode_src(
    cfg: &ModelConfig,
    src: &WeightSource,
    tokens: &[i32],
    caches: &mut [&mut KvCache],
    prec: Precision,
) -> Mat {
    let b = tokens.len();
    assert!(b > 0, "empty decode batch");
    assert_eq!(caches.len(), b, "one kv cache per decoded sequence");
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let w = src.weights();
    for cache in caches.iter() {
        cache.check(cfg);
        assert!(
            cache.len < cache.cap,
            "kv cache full (cap {}): clear and re-prefill the slid window",
            cache.cap
        );
        assert!(!cache.is_empty(), "decode before prefill");
    }

    let embed = w.get("embed");
    let mut x = Mat::zeros(b, d);
    for (s, &tok) in tokens.iter().enumerate() {
        x.row_mut(s).copy_from_slice(embed.row(tok as usize));
    }

    for li in 0..cfg.n_layers {
        let p = format!("layers.{li}.");

        // ---- attention
        let h1 = rms_norm(&x, w.get_vec(&format!("{p}norm1")), cfg.norm_eps);
        let (qf, kf, vf) = src.project_qkv(&h1, &p, prec);
        let mut ctxcat = Mat::zeros(b, d);
        // serial over (sequence, head): decode batches are small and
        // each iteration appends to its own cache
        for s in 0..b {
            let cache = &mut *caches[s];
            let pos = cache.len;
            for h in 0..nh {
                let mut q = qf.row(s)[h * hd..(h + 1) * hd].to_vec();
                let mut k = kf.row(s)[h * hd..(h + 1) * hd].to_vec();
                rope_rotate_row(&mut q, &cache.cos, &cache.sin, pos);
                rope_rotate_row(&mut k, &cache.cos, &cache.sin, pos);
                let si = li * nh + h;
                cache.k[si].row_mut(pos).copy_from_slice(&k);
                cache.v[si]
                    .row_mut(pos)
                    .copy_from_slice(&vf.row(s)[h * hd..(h + 1) * hd]);
                // causal scores + online softmax over positions 0..=pos
                // — exactly row i = pos of the full forward's sweep
                let kc = &cache.k[si];
                let vc = &cache.v[si];
                let mut maxs = f64::NEG_INFINITY;
                let mut srow = vec![0.0; pos + 1];
                for j in 0..=pos {
                    let sc = crate::linalg::dot(&q, kc.row(j)) * scale;
                    srow[j] = sc;
                    maxs = maxs.max(sc);
                }
                let mut denom = 0.0;
                for j in 0..=pos {
                    srow[j] = (srow[j] - maxs).exp();
                    denom += srow[j];
                }
                let crow = &mut ctxcat.row_mut(s)[h * hd..(h + 1) * hd];
                for j in 0..=pos {
                    let pj = srow[j] / denom;
                    let vrow = vc.row(j);
                    for e in 0..hd {
                        crow[e] += pj * vrow[e];
                    }
                }
            }
        }
        let attn_out = src.project(&ctxcat, &format!("{p}attn.wo"), prec);
        for i in 0..b * d {
            x.data[i] += attn_out.data[i];
        }

        // ---- FFN
        let h2 = rms_norm(&x, w.get_vec(&format!("{p}norm2")), cfg.norm_eps);
        let (pre1, up) = src.project_ffn_in(&h2, &p, prec);
        let mut gate = pre1;
        gate.data.iter_mut().for_each(|v| *v = silu(*v));
        let m = gate.hadamard(&up);
        let ffn_out = src.project(&m, &format!("{p}ffn.w2"), prec);
        for i in 0..b * d {
            x.data[i] += ffn_out.data[i];
        }
    }

    for cache in caches.iter_mut() {
        cache.len += 1;
    }

    let xf = rms_norm(&x, w.get_vec("final_norm"), cfg.norm_eps);
    src.project(&xf, "head", prec)
}

/// Batched incremental decode through prepacked panels — the serving
/// engine's per-iteration step.  Row independence of the prepacked
/// driver makes each sequence's logits row bit-identical no matter
/// which decode batch it rides in.
pub fn decode_packed(
    cfg: &ModelConfig,
    pw: &PackedWeights,
    tokens: &[i32],
    caches: &mut [&mut KvCache],
) -> Mat {
    decode_src(cfg, &WeightSource::Packed(pw), tokens, caches, pw.precision)
}

/// Plain-weights incremental decode (f64) — the offline greedy path
/// and the parity oracle's cached half.
pub fn decode_step(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[i32],
    caches: &mut [&mut KvCache],
) -> Mat {
    decode_src(cfg, &WeightSource::Plain(w), tokens, caches, Precision::F64)
}

/// Row-wise softmax.
pub fn softmax(logits: &Mat) -> Mat {
    let mut out = logits.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    out
}

/// Mean next-token cross-entropy (nats).  `targets[r]` is the target of
/// logits row r.
pub fn cross_entropy(logits: &Mat, targets: &[i32]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        total += lse - row[targets[i] as usize];
    }
    total / logits.rows as f64
}

/// KL(P‖Q) per token between two logit matrices (nats).
pub fn kl_divergence(p_logits: &Mat, q_logits: &Mat) -> f64 {
    assert_eq!(p_logits.rows, q_logits.rows);
    let p = softmax(p_logits);
    let mut total = 0.0;
    for i in 0..p.rows {
        let prow = p.row(i);
        let ql = q_logits.row(i);
        let mx = ql.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + ql.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
        let pl = p_logits.row(i);
        let mxp = pl.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lsep = mxp + pl.iter().map(|v| (v - mxp).exp()).sum::<f64>().ln();
        for j in 0..p.cols {
            if prow[j] > 0.0 {
                total += prow[j] * ((pl[j] - lsep) - (ql[j] - lse));
            }
        }
    }
    total / p.rows as f64
}

/// Attention output given candidate QKV weights on a given input panel —
/// the objective evaluator of eq. (60).  `h1` is the (tokens × D) QKV
/// input panel, laid out as b windows of t tokens.
pub fn attention_block_output(
    cfg: &ModelConfig,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    h1: &Mat,
    b: usize,
    t: usize,
) -> Mat {
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let rows = b * t;
    assert_eq!(h1.rows, rows);
    let (cos, sin) = rope_tables(t, hd, cfg.rope_theta);
    let qf = matmul_nt(h1, wq);
    let kf = matmul_nt(h1, wk);
    let vf = matmul_nt(h1, wv);
    // heads are independent — evaluate them across the persistent pool
    // (this sits inside the eq. 60 mixing objective, which is called
    // once per candidate (ε_qr, ε_aw) point)
    let threads = crate::util::threadpool::default_threads().min(nh.max(1));
    let heads: Vec<usize> = (0..nh).collect();
    let head_outs: Vec<Mat> = crate::util::threadpool::parallel_map(
        heads,
        threads,
        |h| {
            let mut q = Mat::zeros(rows, hd);
            let mut k = Mat::zeros(rows, hd);
            let mut v = Mat::zeros(rows, hd);
            for r in 0..rows {
                q.row_mut(r).copy_from_slice(&qf.row(r)[h * hd..(h + 1) * hd]);
                k.row_mut(r).copy_from_slice(&kf.row(r)[h * hd..(h + 1) * hd]);
                v.row_mut(r).copy_from_slice(&vf.row(r)[h * hd..(h + 1) * hd]);
            }
            apply_rope(&mut q, &cos, &sin, t);
            apply_rope(&mut k, &cos, &sin, t);
            let mut ctx_head = Mat::zeros(rows, hd);
            for bi in 0..b {
                let base = bi * t;
                for i in 0..t {
                    let qi = q.row(base + i);
                    let mut maxs = f64::NEG_INFINITY;
                    let mut srow = vec![0.0; i + 1];
                    for j in 0..=i {
                        let s = crate::linalg::dot(qi, k.row(base + j)) * scale;
                        srow[j] = s;
                        maxs = maxs.max(s);
                    }
                    let mut denom = 0.0;
                    for j in 0..=i {
                        srow[j] = (srow[j] - maxs).exp();
                        denom += srow[j];
                    }
                    let orow = ctx_head.row_mut(base + i);
                    for j in 0..=i {
                        let pj = srow[j] / denom;
                        let vrow = v.row(base + j);
                        for e in 0..hd {
                            orow[e] += pj * vrow[e];
                        }
                    }
                }
            }
            ctx_head
        },
    );
    let mut out = Mat::zeros(rows, d);
    for (h, ctx_head) in head_outs.iter().enumerate() {
        for r in 0..rows {
            out.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(ctx_head.row(r));
        }
    }
    out
}

/// Greedy sample continuation (used by the quickstart example).
/// Runs through the [`KvCache`]: one prefill of the prompt window,
/// then one O(t) decode step per token — token-identical to
/// [`greedy_continuation_rescore`] (the pinned oracle), including past
/// `cfg.ctx`, where each slide re-prefills the shifted window exactly
/// as the re-score loop evaluates it.
pub fn greedy_continuation(
    cfg: &ModelConfig,
    w: &Weights,
    prompt: &[i32],
    steps: usize,
) -> Vec<i32> {
    let mut toks = prompt.to_vec();
    if steps == 0 {
        return toks;
    }
    let mut cache = KvCache::new(cfg, cfg.ctx);
    let t0 = toks.len().min(cfg.ctx);
    let out = prefill(cfg, w, &toks[toks.len() - t0..], &mut cache);
    let mut last = out.logits.row(t0 - 1).to_vec();
    for si in 0..steps {
        toks.push(argmax_last(&last) as i32);
        if si + 1 == steps {
            break;
        }
        if cache.is_full() {
            // the window slid: cached positions are stale — re-prefill
            cache.clear();
            let t = toks.len().min(cfg.ctx);
            let out = prefill(cfg, w, &toks[toks.len() - t..], &mut cache);
            last = out.logits.row(t - 1).to_vec();
        } else {
            let tok = [*toks.last().unwrap()];
            let logits = decode_step(cfg, w, &tok, &mut [&mut cache]);
            last = logits.row(0).to_vec();
        }
    }
    toks
}

/// The pre-cache greedy loop: a full windowed re-score per step — the
/// bit-parity oracle [`greedy_continuation`] is pinned against (and
/// the serving bench's O(t²)-per-token baseline).
pub fn greedy_continuation_rescore(
    cfg: &ModelConfig,
    w: &Weights,
    prompt: &[i32],
    steps: usize,
) -> Vec<i32> {
    let mut toks = prompt.to_vec();
    for _ in 0..steps {
        let t = toks.len().min(cfg.ctx);
        let window = &toks[toks.len() - t..];
        let out = forward(cfg, w, window, 1, t, &ForwardOpts::default());
        let last = out.logits.row(t - 1);
        let arg = (0..cfg.vocab)
            .max_by(|&a, &b| last[a].total_cmp(&last[b]))
            .unwrap();
        toks.push(arg as i32);
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (ModelConfig, Weights, Vec<i32>) {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 5);
        let mut rng = Rng::new(9);
        let tokens: Vec<i32> = (0..2 * cfg.ctx)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        (cfg, w, tokens)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let (cfg, w, tokens) = setup();
        let out = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert_eq!(out.logits.rows, 2 * cfg.ctx);
        assert_eq!(out.logits.cols, cfg.vocab);
        assert!(out.logits.is_finite());
    }

    #[test]
    fn capture_panels_have_expected_shapes() {
        let (cfg, w, tokens) = setup();
        let out = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                capture: true,
                tape: false,
                ..ForwardOpts::default()
            },
        );
        let cap = out.capture.unwrap();
        let rows = 2 * cfg.ctx;
        assert_eq!(cap.inputs["layers.0.attn.qkv"].rows, rows);
        assert_eq!(cap.inputs["layers.0.attn.wo"].cols, cfg.d_model);
        assert_eq!(cap.inputs["layers.0.ffn.in"].cols, cfg.d_model);
        assert_eq!(cap.inputs["layers.0.ffn.w2"].cols, cfg.d_ff);
        assert_eq!(cap.residuals["layers.0.ffn.w2"].rows, rows);
        assert_eq!(
            cap.attn_probs[0].len(),
            2 * cfg.n_heads * cfg.ctx * cfg.ctx
        );
        // attention rows sum to 1 (causal softmax)
        let t = cfg.ctx;
        let probs = &cap.attn_probs[0];
        for i in 0..t {
            let row_sum: f64 = (0..t).map(|j| probs[i * t + j]).sum();
            assert!((row_sum - 1.0).abs() < 1e-9, "row {i}: {row_sum}");
        }
    }

    #[test]
    fn input_group_mapping() {
        assert_eq!(input_group("layers.3.attn.wq"), "layers.3.attn.qkv");
        assert_eq!(input_group("layers.3.attn.wv"), "layers.3.attn.qkv");
        assert_eq!(input_group("layers.3.attn.wo"), "layers.3.attn.wo");
        assert_eq!(input_group("layers.0.ffn.w1"), "layers.0.ffn.in");
        assert_eq!(input_group("layers.0.ffn.w2"), "layers.0.ffn.w2");
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let logits = Mat::zeros(5, 64);
        let ce = cross_entropy(&logits, &[0, 1, 2, 3, 4]);
        assert!((ce - (64f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn kl_zero_for_identical() {
        let (cfg, w, tokens) = setup();
        let out = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert!(kl_divergence(&out.logits, &out.logits).abs() < 1e-12);
        // and positive for different models
        let w2 = Weights::random(&cfg, 17);
        let out2 = forward(&cfg, &w2, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert!(kl_divergence(&out.logits, &out2.logits) > 0.0);
    }

    #[test]
    fn attention_block_output_matches_forward_capture() {
        let (cfg, w, tokens) = setup();
        let out = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                capture: true,
                tape: false,
                ..ForwardOpts::default()
            },
        );
        let cap = out.capture.unwrap();
        let h1 = &cap.inputs["layers.0.attn.qkv"];
        let ctx = attention_block_output(
            &cfg,
            w.get("layers.0.attn.wq"),
            w.get("layers.0.attn.wk"),
            w.get("layers.0.attn.wv"),
            h1,
            2,
            cfg.ctx,
        );
        let expect = &cap.inputs["layers.0.attn.wo"];
        assert!(ctx.sub(expect).max_abs() < 1e-9);
    }

    #[test]
    fn rope_backward_is_inverse_rotation() {
        let cfg = ModelConfig::tiny_test();
        let hd = cfg.head_dim();
        let (cos, sin) = rope_tables(6, hd, cfg.rope_theta);
        let mut rng = Rng::new(2);
        let orig = Mat::from_fn(6, hd, |_, _| rng.gaussian());
        let mut x = orig.clone();
        apply_rope(&mut x, &cos, &sin, 6);
        apply_rope_backward(&mut x, &cos, &sin, 6);
        assert!(x.sub(&orig).max_abs() < 1e-12);
    }

    #[test]
    fn f32_forward_close_to_f64() {
        // a config wide enough that the projection gemms clear the
        // packed-path threshold, so f32 mode actually engages
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            ctx: 64,
            ..ModelConfig::tiny_test()
        };
        let w = Weights::random(&cfg, 7);
        let mut rng = Rng::new(13);
        let tokens: Vec<i32> = (0..2 * cfg.ctx)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let o64 = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let o32 = forward(
            &cfg,
            &w,
            &tokens,
            2,
            cfg.ctx,
            &ForwardOpts {
                precision: Precision::F32,
                ..ForwardOpts::default()
            },
        );
        let rel = o32.logits.sub(&o64.logits).frob_norm()
            / o64.logits.frob_norm().max(1e-30);
        assert!(rel > 0.0, "f32 path did not engage");
        assert!(rel < 1e-4, "f32 forward drifted: {rel}");
    }

    #[test]
    fn packed_forward_bit_identical_to_plain_f64() {
        // tiny-model projections either sit below the packed threshold
        // (k ≤ KC ⇒ the serial dot reduces in the same order as the
        // single-KC-block packed tile) or route through the very same
        // driver — so plain and packed forwards must agree bit for bit
        let (cfg, w, tokens) = setup();
        let plain = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        let packed =
            forward_packed(&cfg, &pw, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        assert_eq!(plain.logits.data, packed.logits.data);
    }

    #[test]
    fn packed_forward_f32_close_to_f64() {
        let (cfg, w, tokens) = setup();
        let plain = forward(&cfg, &w, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let pw32 = PackedWeights::new(&cfg, w.clone(), Precision::F32);
        let packed =
            forward_packed(&cfg, &pw32, &tokens, 2, cfg.ctx, &ForwardOpts::default());
        let rel = packed.logits.sub(&plain.logits).frob_norm()
            / plain.logits.frob_norm().max(1e-30);
        assert!(rel < 1e-4, "f32 packed forward drifted: {rel}");
    }

    #[test]
    fn greedy_continuation_extends() {
        let (cfg, w, tokens) = setup();
        let out = greedy_continuation(&cfg, &w, &tokens[..4], 3);
        assert_eq!(out.len(), 7);
        assert!(out.iter().all(|&t| (t as usize) < cfg.vocab));
    }

    #[test]
    fn kv_cache_bytes_accounting() {
        let cfg = ModelConfig::tiny_test();
        let cache = KvCache::new(&cfg, 8);
        assert_eq!(cache.bytes(), KvCache::bytes_for(&cfg, 8));
        assert!(KvCache::bytes_for(&cfg, 8) > KvCache::bytes_for(&cfg, 4));
        assert_eq!(cache.capacity(), 8);
        assert!(cache.is_empty() && !cache.is_full());
    }

    #[test]
    fn cached_decode_bit_identical_to_full_rescore() {
        // feed arbitrary (not greedy) continuations: every decode step's
        // logits must match the last row of a from-scratch forward over
        // the grown window, bit for bit
        let (cfg, w, tokens) = setup();
        let prompt = &tokens[..6];
        let mut cache = KvCache::new(&cfg, cfg.ctx);
        let out = prefill(&cfg, &w, prompt, &mut cache);
        // the prefill is a full forward plus sink copies
        let full = forward(&cfg, &w, prompt, 1, 6, &ForwardOpts::default());
        assert_eq!(out.logits.data, full.logits.data, "prefill != forward");
        assert_eq!(cache.len(), 6);
        let mut toks = prompt.to_vec();
        for step in 0..cfg.ctx - 6 {
            let next = tokens[6 + step];
            let logits = decode_step(&cfg, &w, &[next], &mut [&mut cache]);
            toks.push(next);
            let full =
                forward(&cfg, &w, &toks, 1, toks.len(), &ForwardOpts::default());
            assert_eq!(
                logits.row(0),
                full.logits.row(toks.len() - 1),
                "decode step {step} drifted from the full re-score"
            );
        }
        assert!(cache.is_full());
    }

    #[test]
    fn batched_decode_packed_matches_single_and_plain() {
        // two sequences decoded in one shared batch must produce the
        // same bits as each decoded alone (row independence), and the
        // packed path must match the plain f64 oracle
        let (cfg, w, tokens) = setup();
        let pw = PackedWeights::new(&cfg, w.clone(), Precision::F64);
        let pa = &tokens[..5];
        let pb = &tokens[5..9];
        let mk = |prompt: &[i32]| -> KvCache {
            let mut c = KvCache::new(&cfg, cfg.ctx);
            prefill_packed(
                &cfg,
                &pw,
                prompt,
                1,
                prompt.len(),
                &mut [Some((&mut c, prompt.len()))],
                &ForwardOpts::default(),
            );
            c
        };
        let (mut ca, mut cb) = (mk(pa), mk(pb));
        let (mut ca1, mut cb1) = (mk(pa), mk(pb));
        let mut cp = mk(pa);
        for step in 0..3 {
            let (na, nb) = (tokens[9 + step], tokens[15 + step]);
            let both =
                decode_packed(&cfg, &pw, &[na, nb], &mut [&mut ca, &mut cb]);
            let only_a = decode_packed(&cfg, &pw, &[na], &mut [&mut ca1]);
            let only_b = decode_packed(&cfg, &pw, &[nb], &mut [&mut cb1]);
            assert_eq!(both.row(0), only_a.row(0), "step {step}: seq a");
            assert_eq!(both.row(1), only_b.row(0), "step {step}: seq b");
            let plain = decode_step(&cfg, &w, &[na], &mut [&mut cp]);
            assert_eq!(both.row(0), plain.row(0), "step {step}: packed vs plain");
        }
    }

    #[test]
    fn greedy_cached_matches_rescore_past_ctx() {
        let (cfg, w, tokens) = setup();
        // 4-token prompt + 14 steps crosses ctx = 12, exercising the
        // slide/re-prefill path
        let cached = greedy_continuation(&cfg, &w, &tokens[..4], 14);
        let rescore = greedy_continuation_rescore(&cfg, &w, &tokens[..4], 14);
        assert_eq!(cached, rescore, "cached greedy diverged from the oracle");
        // and a prompt already longer than ctx
        let long = &tokens[..cfg.ctx + 3];
        assert_eq!(
            greedy_continuation(&cfg, &w, long, 5),
            greedy_continuation_rescore(&cfg, &w, long, 5),
        );
    }

    #[test]
    fn argmax_last_breaks_ties_to_the_right() {
        assert_eq!(argmax_last(&[1.0, 3.0, 3.0, 2.0]), 2);
        assert_eq!(argmax_last(&[5.0]), 0);
        // matches the max_by rule the rescore loop uses
        let row = [0.25, 0.5, 0.5, 0.1];
        let via_max_by = (0..row.len())
            .max_by(|&a, &b| row[a].total_cmp(&row[b]))
            .unwrap();
        assert_eq!(argmax_last(&row), via_max_by);
    }
}
