//! Reverse-mode differentiation of the picollama forward pass with
//! respect to the quantizable weight matrices — the engine behind
//! WaterSIC-FT (§4 "Post-quantization finetuning"): the integer codes Z
//! stay frozen, and the continuous rescalers (t, γ) are trained by
//! chaining dL/dŴ through Ŵ = T·(Z∘α)·Γ.
//!
//! Validated against central finite differences in the test suite.

use std::collections::BTreeMap;

use crate::linalg::gemm::matmul;
use crate::linalg::Mat;

use super::transformer::{apply_rope_backward, silu_prime, softmax, Tape};
use super::weights::Weights;
use super::ModelConfig;

/// dL/dlogits for the distillation loss L = KL(P_teacher ‖ P_student),
/// averaged over rows: (softmax(student) − softmax(teacher)) / rows.
pub fn kl_grad(teacher_logits: &Mat, student_logits: &Mat) -> Mat {
    let pt = softmax(teacher_logits);
    let ps = softmax(student_logits);
    let mut g = ps.sub(&pt);
    let scale = 1.0 / g.rows as f64;
    g.data.iter_mut().for_each(|v| *v *= scale);
    g
}

/// dL/dlogits for next-token cross entropy against hard targets.
pub fn ce_grad(student_logits: &Mat, targets: &[i32]) -> Mat {
    let mut g = softmax(student_logits);
    let scale = 1.0 / g.rows as f64;
    for i in 0..g.rows {
        g[(i, targets[i] as usize)] -= 1.0;
    }
    g.data.iter_mut().for_each(|v| *v *= scale);
    g
}

/// Backward of y = rms_norm(x, gain): given dy and x, return dx.
fn rms_norm_backward(dy: &Mat, x: &Mat, gain: &[f64], eps: f64) -> Mat {
    let d = x.cols as f64;
    let mut dx = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let ms = xr.iter().map(|v| v * v).sum::<f64>() / d;
        let r = 1.0 / (ms + eps).sqrt();
        let mut dot = 0.0;
        for j in 0..x.cols {
            dot += dyr[j] * gain[j] * xr[j];
        }
        let coef = r * r * r / d * dot;
        let dxr = dx.row_mut(i);
        for j in 0..x.cols {
            dxr[j] = dyr[j] * gain[j] * r - coef * xr[j];
        }
    }
    dx
}

/// Gradients of the loss with respect to every quantizable matrix.
pub fn backward(
    cfg: &ModelConfig,
    w: &Weights,
    tape: &Tape,
    dlogits: &Mat,
) -> BTreeMap<String, Mat> {
    let (d, nh) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f64).sqrt();
    let rows = tape.x_final.rows;
    let b = rows / cfg.ctx;
    let t = cfg.ctx;
    let mut grads: BTreeMap<String, Mat> = BTreeMap::new();

    // logits = x_final · headᵀ
    let mut dx = matmul(dlogits, w.get("head")); // rows × D
    dx = rms_norm_backward(&dx, &tape.x_final_in, w.get_vec("final_norm"), cfg.norm_eps);

    let (cos, sin) = {
        // rebuild RoPE tables (same as forward)
        let half = hd / 2;
        let mut cos = Mat::zeros(t, half);
        let mut sin = Mat::zeros(t, half);
        for p in 0..t {
            for i in 0..half {
                let freq =
                    p as f64 / cfg.rope_theta.powf(2.0 * i as f64 / hd as f64);
                cos[(p, i)] = freq.cos();
                sin[(p, i)] = freq.sin();
            }
        }
        (cos, sin)
    };

    for li in (0..cfg.n_layers).rev() {
        let p = format!("layers.{li}.");
        let lt = &tape.layers[li];

        // ---- FFN backward: x_out = x_mid + m·W2ᵀ
        let dffn_out = &dx;
        grads.insert(
            format!("{p}ffn.w2"),
            matmul(&dffn_out.transpose(), &lt.m),
        );
        let dm = matmul(dffn_out, w.get(&format!("{p}ffn.w2")));
        let dgate = dm.hadamard(&lt.up);
        let dup = dm.hadamard(&lt.gate);
        let mut dpre1 = dgate;
        for i in 0..dpre1.data.len() {
            dpre1.data[i] *= silu_prime(lt.pre1.data[i]);
        }
        grads.insert(
            format!("{p}ffn.w1"),
            matmul(&dpre1.transpose(), &lt.h2),
        );
        grads.insert(format!("{p}ffn.w3"), matmul(&dup.transpose(), &lt.h2));
        let dh2 = matmul(&dpre1, w.get(&format!("{p}ffn.w1")))
            .add(&matmul(&dup, w.get(&format!("{p}ffn.w3"))));
        let mut dx_mid = dx.add(&rms_norm_backward(
            &dh2,
            &lt.x_mid,
            w.get_vec(&format!("{p}norm2")),
            cfg.norm_eps,
        ));

        // ---- attention backward: x_mid = x_in + ctxcat·Woᵀ
        grads.insert(
            format!("{p}attn.wo"),
            matmul(&dx_mid.transpose(), &lt.ctxcat),
        );
        let dctxcat = matmul(&dx_mid, w.get(&format!("{p}attn.wo")));

        // per-head attention backward → dqf/dkf/dvf (rows × D concat)
        let mut dqf = Mat::zeros(rows, d);
        let mut dkf = Mat::zeros(rows, d);
        let mut dvf = Mat::zeros(rows, d);
        for h in 0..nh {
            let q = &lt.q[h];
            let k = &lt.k[h];
            let v = &lt.v[h];
            let mut dq = Mat::zeros(rows, hd);
            let mut dk = Mat::zeros(rows, hd);
            let mut dv = Mat::zeros(rows, hd);
            for bi in 0..b {
                let base = bi * t;
                let probs = &lt.probs[bi * nh + h];
                for i in 0..t {
                    // dctx for this row/head
                    let dci = &dctxcat.row(base + i)[h * hd..(h + 1) * hd];
                    // dp over support j ≤ i, and dv accumulation
                    let mut dp = vec![0.0; i + 1];
                    for j in 0..=i {
                        let pij = probs[(i, j)];
                        let vj = v.row(base + j);
                        let mut acc = 0.0;
                        for e in 0..hd {
                            acc += dci[e] * vj[e];
                        }
                        dp[j] = acc;
                        let dvj = dv.row_mut(base + j);
                        for e in 0..hd {
                            dvj[e] += pij * dci[e];
                        }
                    }
                    // softmax backward
                    let mut dot = 0.0;
                    for j in 0..=i {
                        dot += probs[(i, j)] * dp[j];
                    }
                    // scores backward
                    let qi = q.row(base + i);
                    for j in 0..=i {
                        let ds = probs[(i, j)] * (dp[j] - dot) * scale;
                        if ds == 0.0 {
                            continue;
                        }
                        let kj = k.row(base + j);
                        let dqi = dq.row_mut(base + i);
                        for e in 0..hd {
                            dqi[e] += ds * kj[e];
                        }
                        let dkj = dk.row_mut(base + j);
                        for e in 0..hd {
                            dkj[e] += ds * qi[e];
                        }
                    }
                }
            }
            apply_rope_backward(&mut dq, &cos, &sin, t);
            apply_rope_backward(&mut dk, &cos, &sin, t);
            for r in 0..rows {
                dqf.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(dq.row(r));
                dkf.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(dk.row(r));
                dvf.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(dv.row(r));
            }
        }
        grads.insert(format!("{p}attn.wq"), matmul(&dqf.transpose(), &lt.h1));
        grads.insert(format!("{p}attn.wk"), matmul(&dkf.transpose(), &lt.h1));
        grads.insert(format!("{p}attn.wv"), matmul(&dvf.transpose(), &lt.h1));
        let dh1 = matmul(&dqf, w.get(&format!("{p}attn.wq")))
            .add(&matmul(&dkf, w.get(&format!("{p}attn.wk"))))
            .add(&matmul(&dvf, w.get(&format!("{p}attn.wv"))));
        let dnorm1 = rms_norm_backward(
            &dh1,
            &lt.x_in,
            w.get_vec(&format!("{p}norm1")),
            cfg.norm_eps,
        );
        dx = dx_mid.add(&dnorm1);
        let _ = &mut dx_mid;
    }
    grads
}

/// Convenience: loss value + per-matrix grads for the KL distillation
/// objective on one token batch.
pub fn kl_loss_and_grads(
    cfg: &ModelConfig,
    w: &Weights,
    teacher_logits: &Mat,
    tokens: &[i32],
    b: usize,
) -> (f64, BTreeMap<String, Mat>) {
    let out = super::transformer::forward(
        cfg,
        w,
        tokens,
        b,
        cfg.ctx,
        &super::transformer::ForwardOpts {
            capture: false,
            tape: true,
            ..Default::default()
        },
    );
    let loss = super::transformer::kl_divergence(teacher_logits, &out.logits);
    let dlogits = kl_grad(teacher_logits, &out.logits);
    let grads = backward(cfg, w, out.tape.as_ref().unwrap(), &dlogits);
    (loss, grads)
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::{cross_entropy, forward, ForwardOpts};
    use crate::util::rng::Rng;

    /// Central finite differences of the CE loss wrt a few entries of a
    /// matrix must match the analytic gradient.
    #[test]
    fn finite_difference_check() {
        let mut cfg = crate::model::ModelConfig::tiny_test();
        cfg.ctx = 6;
        let mut w = Weights::random(&cfg, 42);
        let mut rng = Rng::new(4);
        let b = 2;
        let tokens: Vec<i32> =
            (0..b * cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();
        let targets: Vec<i32> =
            (0..b * cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();

        let loss_of = |w: &Weights| {
            let out = forward(&cfg, w, &tokens, b, cfg.ctx, &ForwardOpts::default());
            cross_entropy(&out.logits, &targets)
        };

        // analytic
        let out = forward(
            &cfg,
            &w,
            &tokens,
            b,
            cfg.ctx,
            &ForwardOpts {
                capture: false,
                tape: true,
                ..Default::default()
            },
        );
        let dlogits = ce_grad(&out.logits, &targets);
        let grads = backward(&cfg, &w, out.tape.as_ref().unwrap(), &dlogits);

        let eps = 1e-5;
        for name in [
            "layers.0.attn.wq",
            "layers.0.attn.wk",
            "layers.0.attn.wv",
            "layers.0.attn.wo",
            "layers.0.ffn.w1",
            "layers.0.ffn.w3",
            "layers.0.ffn.w2",
        ] {
            let g = &grads[name];
            // probe 4 random entries
            let mut prng = Rng::new(7);
            for _ in 0..4 {
                let i = prng.below(g.rows);
                let j = prng.below(g.cols);
                let orig = w.get(name)[(i, j)];
                let mut wp = w.get(name).clone();
                wp[(i, j)] = orig + eps;
                w.set(name, wp);
                let lp = loss_of(&w);
                let mut wm = w.get(name).clone();
                wm[(i, j)] = orig - eps;
                w.set(name, wm);
                let lm = loss_of(&w);
                let mut wr = w.get(name).clone();
                wr[(i, j)] = orig;
                w.set(name, wr);
                let fd = (lp - lm) / (2.0 * eps);
                let an = g[(i, j)];
                assert!(
                    (fd - an).abs() < 1e-4 * (1.0 + fd.abs().max(an.abs())),
                    "{name}[{i},{j}]: fd {fd:.6e} vs analytic {an:.6e}"
                );
            }
        }
    }

    #[test]
    fn kl_grad_zero_at_teacher() {
        let cfg = crate::model::ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<i32> =
            (0..cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();
        let out = forward(&cfg, &w, &tokens, 1, cfg.ctx, &ForwardOpts::default());
        let g = kl_grad(&out.logits, &out.logits);
        assert!(g.max_abs() < 1e-12);
    }

    #[test]
    fn kl_loss_and_grads_runs() {
        let cfg = crate::model::ModelConfig::tiny_test();
        let teacher = Weights::random(&cfg, 1);
        let student = Weights::random(&cfg, 2);
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> =
            (0..cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();
        let tout = forward(&cfg, &teacher, &tokens, 1, cfg.ctx, &ForwardOpts::default());
        let (loss, grads) =
            kl_loss_and_grads(&cfg, &student, &tout.logits, &tokens, 1);
        assert!(loss > 0.0);
        assert_eq!(grads.len(), 7);
        assert!(grads.values().all(|g| g.is_finite()));
    }
}
