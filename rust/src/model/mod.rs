//! The `picollama` model substrate on the Rust side: configuration
//! (parsed from the artifact manifest), weight IO (.npy directories),
//! a native f64 forward pass with calibration capture hooks, and a
//! reverse-mode pass over the quantizable weights (used by WaterSIC-FT).
//!
//! The native forward is the *oracle* twin of the AOT HLO artifact
//! (`runtime::forward`); both are validated against each other.

pub mod autograd;
pub mod transformer;
pub mod weights;

use anyhow::{Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// Architecture hyper-parameters (mirror of python `ModelConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub ctx: usize,
    pub norm_eps: f64,
    pub rope_theta: f64,
    pub n_params: usize,
    pub param_order: Vec<String>,
    pub quantizable: Vec<String>,
    pub bf16_ppl_wiki: f64,
    pub bf16_ppl_web: f64,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Load from `artifacts/models/<name>/meta.json`.
    pub fn load(meta_path: &Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text)?;
        let c = j.req("config")?;
        Ok(ModelConfig {
            name: j.req("name")?.as_str()?.to_string(),
            vocab: c.req("vocab")?.as_usize()?,
            d_model: c.req("d_model")?.as_usize()?,
            n_heads: c.req("n_heads")?.as_usize()?,
            n_layers: c.req("n_layers")?.as_usize()?,
            d_ff: c.req("d_ff")?.as_usize()?,
            ctx: c.req("ctx")?.as_usize()?,
            norm_eps: c.req("norm_eps")?.as_f64()?,
            rope_theta: c.req("rope_theta")?.as_f64()?,
            n_params: j.req("n_params")?.as_usize()?,
            param_order: j
                .req("param_order")?
                .as_arr()?
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Result<_>>()?,
            quantizable: j
                .req("quantizable")?
                .as_arr()?
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Result<_>>()?,
            bf16_ppl_wiki: j.req("bf16_ppl_wiki")?.as_f64()?,
            bf16_ppl_web: j.req("bf16_ppl_web")?.as_f64()?,
        })
    }

    /// A tiny config for unit tests (no artifact needed).
    pub fn tiny_test() -> ModelConfig {
        let mut quantizable = Vec::new();
        let p = "layers.0.";
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            quantizable.push(format!("{p}{w}"));
        }
        for w in ["ffn.w1", "ffn.w3", "ffn.w2"] {
            quantizable.push(format!("{p}{w}"));
        }
        ModelConfig {
            name: "tiny_test".into(),
            vocab: 128,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            ctx: 12,
            norm_eps: 1e-5,
            rope_theta: 10000.0,
            n_params: 0,
            param_order: vec![],
            quantizable,
            bf16_ppl_wiki: 0.0,
            bf16_ppl_web: 0.0,
        }
    }

    /// Number of parameters in the quantizable per-block matrices.
    pub fn quantizable_params(&self) -> usize {
        self.n_layers
            * (4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff)
    }

    /// Shape (out=a, in=n) of a 2-D parameter by name.
    pub fn shape_of(&self, name: &str) -> (usize, usize) {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        if name == "embed" || name == "head" {
            return (v, d);
        }
        if name.ends_with("ffn.w1") || name.ends_with("ffn.w3") {
            return (f, d);
        }
        if name.ends_with("ffn.w2") {
            return (d, f);
        }
        if name.contains("attn.") {
            return (d, d);
        }
        (d, 0) // norms are vectors; caller should special-case
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_config_consistent() {
        let c = ModelConfig::tiny_test();
        assert_eq!(c.head_dim(), 8);
        assert_eq!(c.quantizable.len(), 7 * c.n_layers);
        assert_eq!(c.shape_of("layers.0.ffn.w1"), (32, 16));
        assert_eq!(c.shape_of("layers.0.ffn.w2"), (16, 32));
        assert_eq!(c.shape_of("layers.0.attn.wq"), (16, 16));
        assert_eq!(c.shape_of("head"), (128, 16));
    }

    #[test]
    fn parses_meta_json() {
        let meta = r#"{
          "name": "m", "n_params": 100,
          "config": {"vocab": 256, "d_model": 8, "n_heads": 2,
                     "n_layers": 1, "d_ff": 16, "ctx": 32,
                     "norm_eps": 1e-5, "rope_theta": 10000.0},
          "param_order": ["embed", "head"],
          "param_shapes": {},
          "quantizable": ["layers.0.attn.wq"],
          "bf16_ppl_wiki": 1.5, "bf16_ppl_web": 100.0
        }"#;
        let dir = std::env::temp_dir().join("wsic_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("meta.json");
        std::fs::write(&p, meta).unwrap();
        let c = ModelConfig::load(&p).unwrap();
        assert_eq!(c.d_model, 8);
        assert_eq!(c.param_order.len(), 2);
        assert_eq!(c.bf16_ppl_wiki, 1.5);
    }
}
