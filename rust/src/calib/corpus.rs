//! Corpus handling: load the build-time synthetic corpora, sample
//! deterministic token windows for calibration, and carve a held-out
//! tail for evaluation (the trainer sampled windows uniformly, so the
//! tail is the least-trained-on region we have; the `web` corpus is
//! fully off-domain).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub name: String,
    pub bytes: Vec<u8>,
}

/// Fraction of the corpus reserved (from the tail) for evaluation.
pub const EVAL_TAIL_FRAC: f64 = 0.1;

impl Corpus {
    pub fn load(artifacts: &Path, domain: &str) -> Result<Corpus> {
        let path = artifacts.join(format!("corpus_{domain}.txt"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        ensure!(!bytes.is_empty(), "empty corpus {domain}");
        Ok(Corpus {
            name: domain.to_string(),
            bytes,
        })
    }

    pub fn from_bytes(name: &str, bytes: Vec<u8>) -> Corpus {
        Corpus {
            name: name.to_string(),
            bytes,
        }
    }

    fn eval_start(&self) -> usize {
        ((self.bytes.len() as f64) * (1.0 - EVAL_TAIL_FRAC)) as usize
    }

    /// Sample `count` calibration windows of `ctx`+1 bytes from the head
    /// region; returns (inputs, targets) flattened per window.
    pub fn calib_windows(
        &self,
        count: usize,
        ctx: usize,
        seed: u64,
    ) -> Vec<(Vec<i32>, Vec<i32>)> {
        self.sample_region(0, self.eval_start(), count, ctx, seed)
    }

    /// Deterministic evaluation windows from the held-out tail.
    pub fn eval_windows(
        &self,
        count: usize,
        ctx: usize,
        seed: u64,
    ) -> Vec<(Vec<i32>, Vec<i32>)> {
        self.sample_region(self.eval_start(), self.bytes.len(), count, ctx, seed)
    }

    fn sample_region(
        &self,
        lo: usize,
        hi: usize,
        count: usize,
        ctx: usize,
        seed: u64,
    ) -> Vec<(Vec<i32>, Vec<i32>)> {
        let span = hi.saturating_sub(lo);
        assert!(span > ctx + 1, "corpus region too small");
        let mut rng = Rng::new(seed ^ 0x5EED);
        (0..count)
            .map(|_| {
                let start = lo + rng.below(span - ctx - 1);
                let inp: Vec<i32> = self.bytes[start..start + ctx]
                    .iter()
                    .map(|&b| b as i32)
                    .collect();
                let tgt: Vec<i32> = self.bytes[start + 1..start + ctx + 1]
                    .iter()
                    .map(|&b| b as i32)
                    .collect();
                (inp, tgt)
            })
            .collect()
    }
}

/// Stack windows into flattened (tokens, targets) batches of `b` windows.
pub fn batch_windows(
    windows: &[(Vec<i32>, Vec<i32>)],
    b: usize,
) -> Vec<(Vec<i32>, Vec<i32>)> {
    windows
        .chunks(b)
        .filter(|c| c.len() == b)
        .map(|chunk| {
            let mut toks = Vec::new();
            let mut tgts = Vec::new();
            for (i, t) in chunk {
                toks.extend_from_slice(i);
                tgts.extend_from_slice(t);
            }
            (toks, tgts)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        let text: String = (0..200)
            .map(|i| format!("sentence number {i} is here. "))
            .collect();
        Corpus::from_bytes("test", text.into_bytes())
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let c = corpus();
        for (inp, tgt) in c.calib_windows(5, 32, 1) {
            assert_eq!(inp.len(), 32);
            assert_eq!(tgt.len(), 32);
            assert_eq!(&inp[1..], &tgt[..31]);
        }
    }

    #[test]
    fn calib_and_eval_regions_disjoint() {
        let c = corpus();
        let split = ((c.bytes.len() as f64) * 0.9) as usize;
        // all calib windows start below the split; eval at/after it
        let calib = c.calib_windows(50, 16, 2);
        let eval = c.eval_windows(50, 16, 3);
        assert_eq!(calib.len(), 50);
        assert_eq!(eval.len(), 50);
        // verify eval windows come from tail bytes
        for (inp, _) in &eval {
            let needle: Vec<u8> = inp.iter().map(|&x| x as u8).collect();
            let hay = &c.bytes[split.saturating_sub(17)..];
            assert!(
                hay.windows(16).any(|w| w == needle.as_slice()),
                "eval window not from tail"
            );
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let c = corpus();
        assert_eq!(c.calib_windows(3, 8, 7), c.calib_windows(3, 8, 7));
        assert_ne!(c.calib_windows(3, 8, 7), c.calib_windows(3, 8, 8));
    }

    #[test]
    fn batching_flattens() {
        let c = corpus();
        let w = c.calib_windows(5, 8, 1);
        let batches = batch_windows(&w, 2);
        assert_eq!(batches.len(), 2); // 5th window dropped
        assert_eq!(batches[0].0.len(), 16);
    }
}
