//! Calibration substrate: corpus loading and window sampling, streaming
//! covariance accumulation, attention-importance weighting (eq. 19),
//! and the teacher/student drift statistics collector that feeds §4's
//! corrected objectives.

pub mod attention;
pub mod corpus;
pub mod covariance;
pub mod drift;
