//! Teacher/student statistics collector: runs the native forward with
//! capture on the calibration batches and assembles the per-matrix
//! `LayerStats` (Σ_X, Σ_X̂, Σ_{X,X̂}, Σ_{Δ,X̂}) with optional
//! attention-importance weighting — the data plumbing behind §4's
//! activation-drift correction (Qronos), residual-stream correction,
//! and attention-weighted calibration.

use std::collections::BTreeMap;

use crate::linalg::gemm::Precision;
use crate::linalg::Mat;
use crate::model::transformer::{forward, input_group, Capture, ForwardOpts};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::quant::LayerStats;
use crate::util::threadpool::{default_threads, parallel_map};

use super::attention::row_weights;
use super::covariance::CovAccum;

/// Which corrections to apply when assembling stats for one matrix.
#[derive(Clone, Copy, Debug)]
pub struct StatsOpts {
    /// use student statistics (Σ_X̂, Σ_{X,X̂}) — "Qronos"/QA-LDLQ
    pub drift: bool,
    /// add Σ_{Δ,X̂} for down-projections (attn.wo / ffn.w2)
    pub residual: bool,
    /// weight QKV covariances by teacher attention importance (eq. 19)
    pub attn_weighted: bool,
}

impl Default for StatsOpts {
    fn default() -> Self {
        StatsOpts {
            drift: true,
            residual: true,
            attn_weighted: false,
        }
    }
}

/// The calibration set: token batches plus cached *teacher* captures
/// (the teacher never changes during the pipeline).
pub struct CalibSet {
    pub batches: Vec<Vec<i32>>, // flattened (b × ctx) token batches
    pub b: usize,
    pub teacher_caps: Vec<Capture>,
    pub teacher_logits: Vec<Mat>,
    /// kernel precision for every forward and covariance product this
    /// set performs (`WATERSIC_PRECISION` unless plumbed explicitly)
    pub precision: Precision,
}

impl CalibSet {
    pub fn build(
        cfg: &ModelConfig,
        teacher: &Weights,
        batches: Vec<Vec<i32>>,
        b: usize,
    ) -> CalibSet {
        CalibSet::build_prec(cfg, teacher, batches, b, Precision::from_env())
    }

    /// [`CalibSet::build`] at an explicit kernel precision (the
    /// pipeline threads `PipelineOpts::precision` through here).
    pub fn build_prec(
        cfg: &ModelConfig,
        teacher: &Weights,
        batches: Vec<Vec<i32>>,
        b: usize,
        precision: Precision,
    ) -> CalibSet {
        // batches are independent: fan the teacher passes out over the
        // persistent pool; one capture pass yields both the panels and
        // the logits (the seed ran a second forward for the latter)
        let threads = default_threads().min(batches.len().max(1));
        let refs: Vec<&Vec<i32>> = batches.iter().collect();
        let outs: Vec<(Capture, Mat)> = parallel_map(refs, threads, |toks| {
            let out = forward(
                cfg,
                teacher,
                toks,
                b,
                cfg.ctx,
                &ForwardOpts {
                    capture: true,
                    tape: false,
                    precision,
                },
            );
            (out.capture.unwrap(), out.logits)
        });
        let (caps, logits): (Vec<Capture>, Vec<Mat>) = outs.into_iter().unzip();
        CalibSet {
            batches,
            b,
            teacher_caps: caps,
            teacher_logits: logits,
            precision,
        }
    }

    /// Run the (partially quantized) student over the calibration set
    /// (batch-parallel over the persistent pool).
    pub fn student_pass(&self, cfg: &ModelConfig, student: &Weights) -> Vec<Capture> {
        let threads = default_threads().min(self.batches.len().max(1));
        let refs: Vec<&Vec<i32>> = self.batches.iter().collect();
        parallel_map(refs, threads, |toks| {
            forward(
                cfg,
                student,
                toks,
                self.b,
                cfg.ctx,
                &ForwardOpts {
                    capture: true,
                    tape: false,
                    precision: self.precision,
                },
            )
            .capture
            .unwrap()
        })
    }

    /// Assemble `LayerStats` for one quantizable matrix.
    pub fn stats_for(
        &self,
        cfg: &ModelConfig,
        matrix: &str,
        student_caps: &[Capture],
        opts: StatsOpts,
    ) -> LayerStats {
        let group = input_group(matrix);
        let layer_idx = matrix
            .strip_prefix("layers.")
            .and_then(|s| s.split('.').next())
            .and_then(|s| s.parse::<usize>().ok())
            .expect("matrix name must be layers.<i>.…");
        let is_qkv = group.ends_with("attn.qkv");
        let is_down = matrix.ends_with("attn.wo") || matrix.ends_with("ffn.w2");

        let n = self.teacher_caps[0].inputs[&group].cols;
        let a = if is_down {
            cfg.d_model
        } else {
            0 // Σ_Δ unused
        };
        let mut acc_x = CovAccum::with_precision(n, n, self.precision);
        let mut acc_xh = CovAccum::with_precision(n, n, self.precision);
        let mut acc_x_xh = CovAccum::with_precision(n, n, self.precision);
        let mut acc_d = if is_down && opts.residual {
            Some(CovAccum::with_precision(a, n, self.precision))
        } else {
            None
        };

        for (tc, sc) in self.teacher_caps.iter().zip(student_caps) {
            let x = &tc.inputs[&group];
            let xh = if opts.drift { &sc.inputs[&group] } else { x };
            let w: Option<Vec<f64>> = if is_qkv && opts.attn_weighted {
                Some(row_weights(
                    &tc.attn_probs[layer_idx],
                    self.b,
                    cfg.n_heads,
                    cfg.ctx,
                ))
            } else {
                None
            };
            acc_x.add_weighted(x, x, w.as_deref());
            acc_xh.add_weighted(xh, xh, w.as_deref());
            acc_x_xh.add_weighted(x, xh, w.as_deref());
            if let Some(acc) = acc_d.as_mut() {
                let r = &tc.residuals[matrix];
                let rh = &sc.residuals[matrix];
                let dr = r.sub(rh);
                acc.add_weighted(&dr, xh, w.as_deref());
            }
        }
        LayerStats {
            sigma_x: acc_x.finalize(),
            sigma_xhat: acc_xh.finalize(),
            sigma_x_xhat: acc_x_xh.finalize(),
            sigma_d_xhat: acc_d.map(|a| a.finalize()),
        }
    }

    /// Teacher input panels for one group, concatenated (used by the
    /// mixing objective, eq. 60).
    pub fn teacher_panels(&self, group: &str) -> Vec<&Mat> {
        self.teacher_caps.iter().map(|c| &c.inputs[group]).collect()
    }
}

/// Mean relative Frobenius error between teacher and student panels of
/// a group — the ablation figures' per-layer "relative MSE at the input
/// of matrix X".
pub fn panel_rel_mse(teacher: &[&Mat], student: &[&Mat]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (t, s) in teacher.iter().zip(student) {
        let d = t.sub(s);
        num += d.data.iter().map(|x| x * x).sum::<f64>();
        den += t.data.iter().map(|x| x * x).sum::<f64>();
    }
    num / den.max(1e-300)
}

/// Collect a map matrix-name → input-group panels from student captures.
pub fn student_panels<'a>(caps: &'a [Capture], group: &str) -> Vec<&'a Mat> {
    caps.iter().map(|c| &c.inputs[group]).collect()
}

pub fn _unused() -> BTreeMap<String, ()> {
    BTreeMap::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelConfig, Weights, CalibSet) {
        let cfg = ModelConfig::tiny_test();
        let teacher = Weights::random(&cfg, 11);
        let mut rng = crate::util::rng::Rng::new(5);
        let batches: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                (0..2 * cfg.ctx)
                    .map(|_| rng.below(cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let cs = CalibSet::build(&cfg, &teacher, batches, 2);
        (cfg, teacher, cs)
    }

    #[test]
    fn identical_student_gives_matched_stats_and_zero_drift() {
        let (cfg, teacher, cs) = setup();
        let scaps = cs.student_pass(&cfg, &teacher);
        let stats = cs.stats_for(&cfg, "layers.0.ffn.w2", &scaps, StatsOpts::default());
        assert!(stats.sigma_x.sub(&stats.sigma_xhat).max_abs() < 1e-9);
        assert!(stats.sigma_x.sub(&stats.sigma_x_xhat).max_abs() < 1e-9);
        let d = stats.sigma_d_xhat.unwrap();
        assert!(d.max_abs() < 1e-9, "Σ_Δ must vanish for exact student");
    }

    #[test]
    fn perturbed_student_produces_drift() {
        let (cfg, teacher, cs) = setup();
        let mut student = teacher.clone();
        // corrupt an early matrix so downstream inputs drift
        let mut wq = student.get("layers.0.attn.wq").clone();
        wq.data.iter_mut().for_each(|x| *x *= 0.5);
        student.set("layers.0.attn.wq", wq);
        let scaps = cs.student_pass(&cfg, &student);
        let stats = cs.stats_for(&cfg, "layers.0.ffn.w2", &scaps, StatsOpts::default());
        assert!(stats.sigma_x.sub(&stats.sigma_xhat).max_abs() > 1e-6);
        assert!(stats.sigma_d_xhat.unwrap().max_abs() > 1e-9);
        // rel MSE at the w2 input is positive
        let t_panels = cs.teacher_panels("layers.0.ffn.w2");
        let s_panels = student_panels(&scaps, "layers.0.ffn.w2");
        assert!(panel_rel_mse(&t_panels, &s_panels) > 1e-9);
    }

    #[test]
    fn env_precision_stats_engage_packed_path() {
        // panels sized past the packed-gemm threshold so the
        // env-selected precision (f64 by default; f32 in the rust-f32
        // CI job) actually drives the forward and covariance kernels
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 2,
            d_ff: 64,
            ctx: 32,
            ..ModelConfig::tiny_test()
        };
        let teacher = Weights::random(&cfg, 19);
        let mut rng = crate::util::rng::Rng::new(23);
        let batches: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                (0..2 * cfg.ctx)
                    .map(|_| rng.below(cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let cs = CalibSet::build(&cfg, &teacher, batches, 2);
        assert_eq!(cs.precision, Precision::from_env());
        let scaps = cs.student_pass(&cfg, &teacher);
        let stats = cs.stats_for(&cfg, "layers.0.ffn.w2", &scaps, StatsOpts::default());
        // identical student ⇒ identical captures bitwise ⇒ exact
        // agreement in either precision; values must stay finite
        assert!(stats.sigma_x.sub(&stats.sigma_xhat).max_abs() < 1e-9);
        assert!(stats.sigma_x.is_finite());
        assert!(stats.sigma_d_xhat.unwrap().max_abs() < 1e-9);
    }

    #[test]
    fn attention_weighting_changes_qkv_stats_only() {
        let (cfg, teacher, cs) = setup();
        let scaps = cs.student_pass(&cfg, &teacher);
        let base = cs.stats_for(
            &cfg,
            "layers.0.attn.wq",
            &scaps,
            StatsOpts {
                attn_weighted: false,
                ..StatsOpts::default()
            },
        );
        let weighted = cs.stats_for(
            &cfg,
            "layers.0.attn.wq",
            &scaps,
            StatsOpts {
                attn_weighted: true,
                ..StatsOpts::default()
            },
        );
        assert!(base.sigma_x.sub(&weighted.sigma_x).max_abs() > 1e-12);
        // w2 is unaffected by the flag
        let w2a = cs.stats_for(&cfg, "layers.0.ffn.w2", &scaps, StatsOpts::default());
        let w2b = cs.stats_for(
            &cfg,
            "layers.0.ffn.w2",
            &scaps,
            StatsOpts {
                attn_weighted: true,
                ..StatsOpts::default()
            },
        );
        assert!(w2a.sigma_x.sub(&w2b.sigma_x).max_abs() < 1e-15);
    }

    #[test]
    fn no_drift_option_collapses_to_teacher_stats() {
        let (cfg, teacher, cs) = setup();
        let mut student = teacher.clone();
        let mut w1 = student.get("layers.0.ffn.w1").clone();
        w1.data.iter_mut().for_each(|x| *x += 0.1);
        student.set("layers.0.ffn.w1", w1);
        let scaps = cs.student_pass(&cfg, &student);
        let stats = cs.stats_for(
            &cfg,
            "layers.0.ffn.w2",
            &scaps,
            StatsOpts {
                drift: false,
                residual: false,
                attn_weighted: false,
            },
        );
        assert!(stats.sigma_x.sub(&stats.sigma_xhat).max_abs() < 1e-15);
        assert!(stats.sigma_d_xhat.is_none());
    }
}
