//! Streaming (cross-)covariance accumulators over activation panels.
//! Covariances here are *uncentered* second moments E[x xᵀ], matching
//! the GPTQ/WaterSIC Hessian convention Σ_X = E[XXᵀ].
//!
//! Panels stream through the packed gemm substrate: the symmetric
//! auto-moment case (same panel, unit weights) goes through the
//! blocked-symmetric `gram_acc` (half the flops, parallel blocks), and
//! the general/weighted case through the packed `matmul_tn_acc`
//! (C += XᵀY) with row weights folded into a scaled copy of X.
//!
//! Both paths are precision-gated: in f32 mode
//! ([`CovAccum::with_precision`], fed by `WATERSIC_PRECISION`) the
//! panels pack and multiply in f32 while the running sum stays f64 —
//! accumulate in f64, store/pack in f32.

use crate::linalg::gemm::{gram_acc_prec, matmul_tn_acc_prec, Precision};
use crate::linalg::Mat;

/// Accumulates Σ = E[x yᵀ] from row panels, optionally with per-row
/// weights (attention-importance weighting plugs in here).
#[derive(Clone, Debug)]
pub struct CovAccum {
    pub nx: usize,
    pub ny: usize,
    sum: Mat,
    weight: f64,
    /// true while every update so far used the mirror-symmetric gram
    /// path — the invariant that makes the next such update valid
    symmetric: bool,
    /// kernel precision for the panel products (the sum stays f64)
    precision: Precision,
}

impl CovAccum {
    pub fn new(nx: usize, ny: usize) -> CovAccum {
        CovAccum::with_precision(nx, ny, Precision::F64)
    }

    /// Accumulator whose panel gemms run at `precision`; the running
    /// f64 sum (and therefore `finalize`) is unaffected by rounding
    /// across updates, only within each streamed panel product.
    pub fn with_precision(nx: usize, ny: usize, precision: Precision) -> CovAccum {
        CovAccum {
            nx,
            ny,
            sum: Mat::zeros(nx, ny),
            weight: 0.0,
            symmetric: true,
            precision,
        }
    }

    /// Add panels X (rows × nx) and Y (rows × ny) with unit weights.
    pub fn add(&mut self, x: &Mat, y: &Mat) {
        self.add_weighted(x, y, None);
    }

    /// Add with optional per-row weights.
    pub fn add_weighted(&mut self, x: &Mat, y: &Mat, w: Option<&[f64]>) {
        assert_eq!(x.rows, y.rows);
        assert_eq!(x.cols, self.nx);
        assert_eq!(y.cols, self.ny);
        let wsum = match w {
            Some(w) => {
                assert_eq!(w.len(), x.rows);
                w.iter().sum::<f64>()
            }
            None => x.rows as f64,
        };
        let same_panel = std::ptr::eq(x, y) && self.nx == self.ny;
        if w.is_none() && same_panel && self.symmetric {
            gram_acc_prec(x, &mut self.sum, self.precision);
        } else {
            self.symmetric = false;
            match w {
                None => matmul_tn_acc_prec(x, y, &mut self.sum, self.precision),
                Some(w) => {
                    // fold the row weights into one factor: Σ += Xᵀdiag(w)Y
                    let mut xs = x.clone();
                    for (r, &wr) in w.iter().enumerate() {
                        if wr == 0.0 {
                            xs.row_mut(r).fill(0.0);
                        } else if wr != 1.0 {
                            xs.row_mut(r).iter_mut().for_each(|v| *v *= wr);
                        }
                    }
                    matmul_tn_acc_prec(&xs, y, &mut self.sum, self.precision);
                }
            }
        }
        self.weight += wsum;
    }

    /// Normalized covariance estimate.
    pub fn finalize(&self) -> Mat {
        self.sum.scale(1.0 / self.weight.max(1e-300))
    }

    pub fn total_weight(&self) -> f64 {
        self.weight
    }
}

/// Symmetric auto-covariance helper: Σ_X = E[x xᵀ].
pub fn covariance(x: &Mat) -> Mat {
    let mut acc = CovAccum::new(x.cols, x.cols);
    acc.add(x, x);
    acc.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_for_white_data() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(20_000, 4, |_, _| rng.gaussian());
        let c = covariance(&x);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (c[(i, j)] - expect).abs() < 0.05,
                    "({i},{j}) = {}",
                    c[(i, j)]
                );
            }
        }
    }

    #[test]
    fn weights_change_estimate() {
        // two clusters; weighting one to zero leaves the other's moment
        let x = Mat::from_vec(4, 1, vec![1.0, 1.0, 3.0, 3.0]);
        let mut acc = CovAccum::new(1, 1);
        acc.add_weighted(&x, &x, Some(&[1.0, 1.0, 0.0, 0.0]));
        assert!((acc.finalize()[(0, 0)] - 1.0).abs() < 1e-12);
        let mut acc2 = CovAccum::new(1, 1);
        acc2.add_weighted(&x, &x, Some(&[0.0, 0.0, 1.0, 1.0]));
        assert!((acc2.finalize()[(0, 0)] - 9.0).abs() < 1e-12);
    }

    #[test]
    fn cross_covariance_is_not_symmetric() {
        let x = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = Mat::from_vec(2, 2, vec![0.0, 2.0, 1.0, 0.0]);
        let mut acc = CovAccum::new(2, 2);
        acc.add(&x, &y);
        let c = acc.finalize();
        assert!((c[(0, 1)] - 1.0).abs() < 1e-12);
        assert!((c[(1, 0)] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f32_accumulation_close_to_f64() {
        // the f32 panel path (gram + weighted cross-moment) must agree
        // with the f64 reference to f32 rounding, panel sizes chosen to
        // clear the packed threshold
        let mut rng = Rng::new(9);
        let x = Mat::from_fn(400, 48, |_, _| rng.gaussian());
        let mut a64 = CovAccum::new(48, 48);
        a64.add(&x, &x);
        let mut a32 = CovAccum::with_precision(48, 48, Precision::F32);
        a32.add(&x, &x);
        let c64 = a64.finalize();
        let c32 = a32.finalize();
        let rel = c32.sub(&c64).frob_norm() / c64.frob_norm();
        assert!(rel > 0.0, "f32 path did not engage");
        assert!(rel < 1e-5, "f32 gram accumulation drifted: {rel}");

        let ws: Vec<f64> = (0..400).map(|r| 0.5 + (r % 3) as f64).collect();
        let mut w64 = CovAccum::new(48, 48);
        w64.add_weighted(&x, &x, Some(&ws));
        let mut w32 = CovAccum::with_precision(48, 48, Precision::F32);
        w32.add_weighted(&x, &x, Some(&ws));
        let c64 = w64.finalize();
        let c32 = w32.finalize();
        let rel = c32.sub(&c64).frob_norm() / c64.frob_norm();
        assert!(rel < 1e-5, "f32 weighted accumulation drifted: {rel}");
    }

    #[test]
    fn incremental_equals_batch() {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(64, 3, |_, _| rng.gaussian());
        let full = covariance(&x);
        let mut acc = CovAccum::new(3, 3);
        let half1 = x.submatrix(&(0..32).collect::<Vec<_>>(), &[0, 1, 2]);
        let half2 = x.submatrix(&(32..64).collect::<Vec<_>>(), &[0, 1, 2]);
        acc.add(&half1, &half1);
        acc.add(&half2, &half2);
        assert!(acc.finalize().sub(&full).max_abs() < 1e-12);
    }
}
