//! Streaming (cross-)covariance accumulators over activation panels.
//! Covariances here are *uncentered* second moments E[x xᵀ], matching
//! the GPTQ/WaterSIC Hessian convention Σ_X = E[XXᵀ].
//!
//! Panels stream through the packed gemm substrate: the symmetric
//! auto-moment case (same panel, unit weights) goes through the
//! blocked-symmetric `gram_acc` (half the flops, parallel blocks), and
//! the general/weighted case through the packed `matmul_tn_acc`
//! (C += XᵀY) with row weights folded into a scaled copy of X.

use crate::linalg::gemm::{gram_acc, matmul_tn_acc};
use crate::linalg::Mat;

/// Accumulates Σ = E[x yᵀ] from row panels, optionally with per-row
/// weights (attention-importance weighting plugs in here).
#[derive(Clone, Debug)]
pub struct CovAccum {
    pub nx: usize,
    pub ny: usize,
    sum: Mat,
    weight: f64,
    /// true while every update so far used the mirror-symmetric gram
    /// path — the invariant that makes the next such update valid
    symmetric: bool,
}

impl CovAccum {
    pub fn new(nx: usize, ny: usize) -> CovAccum {
        CovAccum {
            nx,
            ny,
            sum: Mat::zeros(nx, ny),
            weight: 0.0,
            symmetric: true,
        }
    }

    /// Add panels X (rows × nx) and Y (rows × ny) with unit weights.
    pub fn add(&mut self, x: &Mat, y: &Mat) {
        self.add_weighted(x, y, None);
    }

    /// Add with optional per-row weights.
    pub fn add_weighted(&mut self, x: &Mat, y: &Mat, w: Option<&[f64]>) {
        assert_eq!(x.rows, y.rows);
        assert_eq!(x.cols, self.nx);
        assert_eq!(y.cols, self.ny);
        let wsum = match w {
            Some(w) => {
                assert_eq!(w.len(), x.rows);
                w.iter().sum::<f64>()
            }
            None => x.rows as f64,
        };
        let same_panel = std::ptr::eq(x, y) && self.nx == self.ny;
        if w.is_none() && same_panel && self.symmetric {
            gram_acc(x, &mut self.sum);
        } else {
            self.symmetric = false;
            match w {
                None => matmul_tn_acc(x, y, &mut self.sum),
                Some(w) => {
                    // fold the row weights into one factor: Σ += Xᵀdiag(w)Y
                    let mut xs = x.clone();
                    for (r, &wr) in w.iter().enumerate() {
                        if wr == 0.0 {
                            xs.row_mut(r).fill(0.0);
                        } else if wr != 1.0 {
                            xs.row_mut(r).iter_mut().for_each(|v| *v *= wr);
                        }
                    }
                    matmul_tn_acc(&xs, y, &mut self.sum);
                }
            }
        }
        self.weight += wsum;
    }

    /// Normalized covariance estimate.
    pub fn finalize(&self) -> Mat {
        self.sum.scale(1.0 / self.weight.max(1e-300))
    }

    pub fn total_weight(&self) -> f64 {
        self.weight
    }
}

/// Symmetric auto-covariance helper: Σ_X = E[x xᵀ].
pub fn covariance(x: &Mat) -> Mat {
    let mut acc = CovAccum::new(x.cols, x.cols);
    acc.add(x, x);
    acc.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_for_white_data() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(20_000, 4, |_, _| rng.gaussian());
        let c = covariance(&x);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (c[(i, j)] - expect).abs() < 0.05,
                    "({i},{j}) = {}",
                    c[(i, j)]
                );
            }
        }
    }

    #[test]
    fn weights_change_estimate() {
        // two clusters; weighting one to zero leaves the other's moment
        let x = Mat::from_vec(4, 1, vec![1.0, 1.0, 3.0, 3.0]);
        let mut acc = CovAccum::new(1, 1);
        acc.add_weighted(&x, &x, Some(&[1.0, 1.0, 0.0, 0.0]));
        assert!((acc.finalize()[(0, 0)] - 1.0).abs() < 1e-12);
        let mut acc2 = CovAccum::new(1, 1);
        acc2.add_weighted(&x, &x, Some(&[0.0, 0.0, 1.0, 1.0]));
        assert!((acc2.finalize()[(0, 0)] - 9.0).abs() < 1e-12);
    }

    #[test]
    fn cross_covariance_is_not_symmetric() {
        let x = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = Mat::from_vec(2, 2, vec![0.0, 2.0, 1.0, 0.0]);
        let mut acc = CovAccum::new(2, 2);
        acc.add(&x, &y);
        let c = acc.finalize();
        assert!((c[(0, 1)] - 1.0).abs() < 1e-12);
        assert!((c[(1, 0)] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn incremental_equals_batch() {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(64, 3, |_, _| rng.gaussian());
        let full = covariance(&x);
        let mut acc = CovAccum::new(3, 3);
        let half1 = x.submatrix(&(0..32).collect::<Vec<_>>(), &[0, 1, 2]);
        let half2 = x.submatrix(&(32..64).collect::<Vec<_>>(), &[0, 1, 2]);
        acc.add(&half1, &half1);
        acc.add(&half2, &half2);
        assert!(acc.finalize().sub(&full).max_abs() < 1e-12);
    }
}
