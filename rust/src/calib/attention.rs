//! Attention-weighted calibration (eq. 19): per-token importance
//!
//!   p_j = 1/(N_H (T−j)) Σ_h Σ_{i≥j} α_{h,i,j}
//!
//! computed from the *teacher's* attention probabilities, used to weight
//! the QKV covariance estimates so tokens that are attended to (e.g.
//! attention sinks) are quantized with higher fidelity.

/// Compute p_j for one sequence given flattened (H, T, T) attention
/// probabilities of that sequence.
pub fn token_importance(probs_ht_t: &[f64], n_heads: usize, t: usize) -> Vec<f64> {
    assert_eq!(probs_ht_t.len(), n_heads * t * t);
    let mut p = vec![0.0f64; t];
    for j in 0..t {
        let mut acc = 0.0;
        for h in 0..n_heads {
            let base = h * t * t;
            for i in j..t {
                acc += probs_ht_t[base + i * t + j];
            }
        }
        // paper normalizes by (T − j); at j = T−1 that is 1
        p[j] = acc / (n_heads as f64 * (t - j) as f64);
    }
    p
}

/// Expand per-sequence importances to per-row weights for a (B·T)-row
/// panel, normalized to mean 1 so weighted and unweighted covariances
/// share a scale (required for the ε_aw interpolation of eq. 59).
pub fn row_weights(probs_bhtt: &[f64], b: usize, n_heads: usize, t: usize) -> Vec<f64> {
    assert_eq!(probs_bhtt.len(), b * n_heads * t * t);
    let mut w = Vec::with_capacity(b * t);
    for bi in 0..b {
        let seq = &probs_bhtt[bi * n_heads * t * t..(bi + 1) * n_heads * t * t];
        w.extend(token_importance(seq, n_heads, t));
    }
    let mean = w.iter().sum::<f64>() / w.len() as f64;
    if mean > 0.0 {
        w.iter_mut().for_each(|x| *x /= mean);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_attention_gives_uniformish_importance() {
        // causal uniform: α_{i,j} = 1/(i+1) for j ≤ i
        let t = 6;
        let mut probs = vec![0.0; t * t];
        for i in 0..t {
            for j in 0..=i {
                probs[i * t + j] = 1.0 / (i + 1) as f64;
            }
        }
        let p = token_importance(&probs, 1, t);
        // p_j = (1/(T−j)) Σ_{i≥j} 1/(i+1) — decreasing in j except the
        // sink effect at j=0
        assert!(p[0] > p[t - 2]);
        // last token attends only to itself at weight 1/(t)… p_{T-1} =
        // α_{T-1,T-1} = 1/T
        assert!((p[t - 1] - 1.0 / t as f64).abs() < 1e-12);
    }

    #[test]
    fn sink_token_gets_high_weight() {
        // all queries attend fully to token 0 (attention sink)
        let t = 5;
        let mut probs = vec![0.0; t * t];
        for i in 0..t {
            probs[i * t] = 1.0;
        }
        let p = token_importance(&probs, 1, t);
        assert!((p[0] - 1.0).abs() < 1e-12);
        for j in 1..t {
            assert_eq!(p[j], 0.0);
        }
    }

    #[test]
    fn row_weights_mean_one() {
        let (b, h, t) = (2, 3, 4);
        let mut probs = vec![0.0; b * h * t * t];
        // causal softmax-like rows
        for blk in 0..b * h {
            for i in 0..t {
                for j in 0..=i {
                    probs[blk * t * t + i * t + j] = 1.0 / (i + 1) as f64;
                }
            }
        }
        let w = row_weights(&probs, b, h, t);
        assert_eq!(w.len(), b * t);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
        assert!(w.iter().all(|&x| x >= 0.0));
    }
}
