//! `watersic` — CLI entrypoint for the WaterSIC reproduction.
//!
//! Subcommands:
//!   quantize   quantize a picollama model to a .wsic container
//!   eval       evaluate a container (PPL / BPB / KL / probes)
//!   serve      serve a .wsic container (micro-batched inference)
//!   repro      regenerate a paper table/figure (see DESIGN.md §4)
//!   selftest   cross-validate PJRT artifacts against the native oracle
//!   info       print artifact/model inventory

use anyhow::{bail, Context, Result};

use watersic::coordinator::container::Container;
use watersic::coordinator::{quantize_model, Algo};
use watersic::experiments::{self, Ctx};
use watersic::model::weights::PackedWeights;
use watersic::runtime::server as serve;
use watersic::runtime::{reactor, Precision, ServeOpts, Server};
use watersic::util::cli::Args;

const USAGE: &str = "\
watersic — WaterSIC: IT-(near)-optimal linear layer quantization (repro)

USAGE:
  watersic quantize  [--model picollama_s] [--rate 2.0] [--algo watersic|hgptq|hrtn|rtn|gptq]
                     [--ft] [--mixing] [--out model.wsic] [--fast] [--no-engine]
  watersic eval      --container model.wsic [--model picollama_s] [--corpus wiki|web]
  watersic serve     --container model.wsic [--model picollama_s] [--addr 127.0.0.1:7878]
                     [--batch 8] [--flush-us 500] [--loadtest N [--requests M]
                      [--gen-frac 0.5] [--heavy-tail] [--max-steps 16]]
                     [--open-rps R [--duration-s S]]
  watersic repro     <id> [--fast] [--no-engine]
                     ids: theory fig1 table1|fig2 table2|fig3 fig4 fig5 table6
                          ablate fig11 fig12 mixing table7 table15 tasks all
  watersic selftest  [--no-engine]
  watersic info

SERVING:
  `serve` dequantizes the container once, prepacks every projection
  matrix into NR-column GEMM panels (no per-call weight packing), and
  runs iteration-level continuous batching: each scheduler step batches
  new prefills with one shared KV-cached decode forward over every
  in-flight generation, and sequences join/leave at step granularity.
  The TCP front door speaks line-delimited JSON:
      {\"tokens\": [1, 2, 3]}             -> {\"len\", \"next\", \"nll\", \"batched_with\"}
      {\"prompt\": [1, 2], \"steps\": 8}    -> {\"tokens\": [..], \"steps\", \"ttft_ms\"}
  (`\"max_tokens\"` aliases `\"steps\"`; both are capped per request by
  WATERSIC_SERVE_MAX_STEPS.)  `--loadtest N` skips the socket and
  drives the server in-process with N concurrent clients (M requests
  each), printing throughput, score latency, and TTFT/inter-token
  percentiles; `--gen-frac F` makes a fraction F of requests greedy
  generations and `--heavy-tail` draws their lengths Pareto-style.
  `--model tiny` serves the synthetic tiny model (zero artifacts
  needed; same weights `quantize --model tiny` uses).

  The TCP front door is an event-driven reactor (epoll/kqueue; falls
  back to thread-per-connection where neither exists) with a hard
  connection cap and per-connection idle/write-stall timeouts.
  Admission is bounded: when the request queue is full the server
  sheds instead of stalling, answering
      {\"error\": \"overloaded\", \"retry_after_ms\": N}
  immediately (N estimated from queue depth and the EWMA scheduler
  step time — back off at least that long before retrying).  Requests
  may carry \"deadline_ms\"; expired work is cancelled at step
  granularity and its KV bytes freed (WATERSIC_SERVE_DEADLINE_MS sets
  a default for requests that don't).  `--open-rps R` drives the
  in-process server open-loop at a fixed offered rate for S seconds,
  printing the shed fraction and accepted-latency percentiles.  ^C
  drains: accepting stops, in-flight work finishes (or hits its
  deadline), responses flush, then the process exits.

ENGINE OPTIONS (env):
  every WATERSIC_* knob is read through the util::env registry; this
  list is pinned to it by a unit test, so it cannot go stale.
  WATERSIC_PRECISION={f64,f32}     kernel/pack precision (default f64)
  WATERSIC_THREADS=N               worker-pool width (outputs bit-identical across N)
  WATERSIC_SIMD=scalar             force the scalar kernel rung (default: auto-detect)
  WATERSIC_LOG=1                   enable debug-level logging (any value)
  WATERSIC_ARTIFACTS=DIR           AOT artifacts dir (default: walk up for artifacts/)
  WATERSIC_PREPARE_LOOKAHEAD=N     prepared layers alive at once while quantizing (default 2)
  WATERSIC_SERVE_BATCH=N           max prefill rows / active generations per step (default 8)
  WATERSIC_SERVE_FLUSH_US=N        partial-batch flush deadline in us (default 500)
  WATERSIC_SERVE_KV_BUDGET=N       KV-cache byte budget across in-flight sequences (default 1 GiB)
  WATERSIC_SERVE_MAX_STEPS=N       per-request generation-step cap (default 256)
  WATERSIC_SERVE_QUEUE=N           bounded admission-queue depth; overflow sheds (default 64)
  WATERSIC_SERVE_DEADLINE_MS=N     default per-request deadline, 0 = off (default 0)
  WATERSIC_SERVE_MAX_CONNS=N       concurrent front-door connection cap (default 1024)
  WATERSIC_SERVE_IDLE_MS=N         per-connection idle timeout (default 60000)
  WATERSIC_SERVE_WRITE_MS=N        per-connection write-stall timeout (default 10000)
  WATERSIC_SERVE_WEIGHTS={dequant,coded}  weight residency: eager panels or quantized
                                   codes decoded inside the GEMM pack stage; responses
                                   are byte-identical either way (default dequant)
  WATERSIC_FAULT=SPEC              deterministic fault plan (fault-inject builds only)
  WATERSIC_BENCH_DIR=DIR           where cargo bench writes BENCH_*.json (default .)
  WATERSIC_BENCH_ENFORCE=1         turn bench speedup targets into hard gates
  WATERSIC_SERVE_CLIENTS=N         bench_serve: concurrent load-test clients (default 8)
  WATERSIC_SERVE_REQUESTS=N        bench_serve: requests per load-test client (default 8)
";

fn main() {
    env_logger_lite();
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            eprintln!("{USAGE}");
            1
        }
    };
    std::process::exit(code);
}

fn env_logger_lite() {
    // minimal logger: honor WATERSIC_LOG for debug prints
    struct L;
    impl log::Log for L {
        fn enabled(&self, _: &log::Metadata) -> bool {
            true
        }
        fn log(&self, record: &log::Record) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if watersic::util::env::is_set("WATERSIC_LOG") {
        log::LevelFilter::Debug
    } else {
        log::LevelFilter::Warn
    });
}

fn parse_algo(s: &str) -> Result<Algo> {
    Ok(match s {
        "watersic" => Algo::WaterSic,
        "hgptq" | "huffman-gptq" => Algo::HuffGptq,
        "hrtn" | "huffman-rtn" => Algo::HuffRtn,
        "rtn" => Algo::Rtn { bits: 4 },
        "gptq" => Algo::Gptq { maxq: 7 },
        other => bail!("unknown algo {other:?}"),
    })
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match cmd {
        "quantize" => cmd_quantize(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "repro" => {
            let id = args
                .positional
                .get(1)
                .context("repro needs an experiment id")?;
            let ctx = Ctx::new(args.bool("fast"), !args.bool("no-engine"))?;
            experiments::run(id, &ctx)
        }
        "selftest" => cmd_selftest(args),
        "sweep" => cmd_sweep(args),
        "info" => cmd_info(),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}"),
    }
}

/// The zero-artifact synthetic model names (`experiments::
/// synthetic_tiny_setup`) accepted by `quantize` and `serve`.
fn is_synthetic_model(name: &str) -> bool {
    matches!(name, "tiny" | "tiny_test" | "synthetic")
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.str_or("model", "picollama_s");
    let rate = args.f64_or("rate", 2.0)?;
    let algo = parse_algo(&args.str_or("algo", "watersic"))?;
    let out = args.str_or("out", "model.wsic");
    let (cfg, teacher, corpus, opts, engine) = if is_synthetic_model(&model) {
        // fully deterministic, artifact-free path — CI's end-to-end
        // determinism gate quantizes this twice and byte-compares
        if !matches!(algo, Algo::WaterSic) {
            bail!("the synthetic tiny model supports --algo watersic only");
        }
        if args.bool("ft") {
            bail!("the synthetic tiny model does not support --ft");
        }
        if args.str_opt("calib").is_some() {
            bail!("the synthetic tiny model uses its built-in corpus (drop --calib)");
        }
        let (cfg, teacher, corpus) = experiments::synthetic_tiny_setup();
        let mut opts = experiments::synthetic_tiny_opts(rate);
        opts.mixing = args.bool("mixing");
        (cfg, teacher, corpus, opts, None)
    } else {
        let ctx = Ctx::new(args.bool("fast"), !args.bool("no-engine"))?;
        let (cfg, teacher) = ctx.load_model(&model)?;
        let corpus = ctx.load_corpus(&args.str_or("calib", "wiki"))?;
        let mut opts =
            experiments::llm::pipeline_opts(&ctx, algo, rate, args.bool("ft"));
        opts.mixing = args.bool("mixing");
        (cfg, teacher, corpus, opts, ctx.engine)
    };
    println!(
        "quantizing {model} with {} @ {rate} bits (calib: {}, engine: {})…",
        algo.name(),
        corpus.name,
        engine.is_some()
    );
    let qm = quantize_model(&cfg, &teacher, &corpus, &opts, engine.as_ref())?;
    println!(
        "avg rate {:.3} bits/weight  ({} matrices, {:.1}s)",
        qm.report.avg_rate,
        qm.report.matrices.len(),
        qm.report.wall_secs
    );
    for m in &qm.report.matrices {
        println!(
            "  {:<22} H={:.3} R={:.3} relMSE={:.3e} dead={} {}",
            m.name,
            m.entropy_bits,
            m.rate_bits,
            m.rel_mse_weights,
            m.dead_cols,
            if m.via_artifact { "[pjrt]" } else { "[native]" }
        );
    }
    let container = Container::new(&cfg.name, qm.quants.clone());
    container.save(std::path::Path::new(&out))?;
    println!(
        "wrote {out} ({:.1} KiB measured)",
        container.size_bytes() as f64 / 1024.0
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ctx = Ctx::new(args.bool("fast"), !args.bool("no-engine"))?;
    let path = args
        .str_opt("container")
        .context("--container required")?
        .to_string();
    let container = Container::load(std::path::Path::new(&path))?;
    let model = args.str_or("model", &container.model_name);
    let (cfg, teacher) = ctx.load_model(&model)?;
    let mut student = teacher.clone();
    for (name, q) in &container.quants {
        student.set(name, q.dequant());
    }
    let domain = args.str_or("corpus", "wiki");
    let corpus = ctx.load_corpus(&domain)?;
    let n_eval = args.usize_or("windows", 48)?;
    let windows = corpus.eval_windows(n_eval, cfg.ctx, 1234);
    let ppl = match &ctx.engine {
        Some(e) => watersic::eval::perplexity_runtime(e, &cfg, &student, &windows, 8)
            .unwrap_or_else(|_| {
                watersic::eval::perplexity_native(&cfg, &student, &windows)
            }),
        None => watersic::eval::perplexity_native(&cfg, &student, &windows),
    };
    let kl = watersic::eval::kl_to_teacher(
        &cfg,
        &teacher,
        &student,
        &windows[..windows.len().min(12)],
    );
    let probes = watersic::eval::probe_suite(&cfg, &student, &windows);
    println!(
        "container : {path} ({:.1} KiB)",
        container.size_bytes() as f64 / 1024.0
    );
    println!("model     : {model}  corpus: {domain}  windows: {n_eval}");
    println!(
        "PPL       : {ppl:.4}   BPB: {:.4}",
        watersic::eval::bits_per_byte(ppl)
    );
    println!("KL(T‖S)   : {kl:.5} nats/token");
    println!(
        "probes    : top1 {:.4}  digits {:.4}  word-start {:.4}  ws {:.4}",
        probes.top1, probes.digits, probes.word_start, probes.whitespace
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny");
    let prec = Precision::from_env();
    let (cfg, base) = if is_synthetic_model(&model) {
        let (cfg, w, _) = experiments::synthetic_tiny_setup();
        (cfg, w)
    } else {
        let ctx = Ctx::new(true, false)?;
        ctx.load_model(&model)?
    };
    let opts = ServeOpts {
        batch_max: args.usize_or("batch", serve::serve_batch_from_env())?.max(1),
        flush: std::time::Duration::from_micros(
            args.usize_or("flush-us", serve::serve_flush_us_from_env() as usize)? as u64,
        ),
        kv_budget: serve::serve_kv_budget_from_env(),
        max_steps: serve::serve_max_steps_from_env(),
        queue_max: serve::serve_queue_from_env(),
        deadline: serve::serve_deadline_from_env(),
    };
    println!(
        "engine    : batch_max {}, flush {:?}, precision {}, kv_budget {:.1} MiB, max_steps {}",
        opts.batch_max,
        opts.flush,
        prec.name(),
        opts.kv_budget as f64 / (1024.0 * 1024.0),
        opts.max_steps
    );
    println!(
        "admission : queue_max {}, default deadline {}",
        opts.queue_max,
        match opts.deadline {
            Some(d) => format!("{d:?}"),
            None => "off".to_string(),
        }
    );
    let server = match args.str_opt("container") {
        Some(path) => {
            let container = Container::load(std::path::Path::new(path))?;
            println!(
                "container : {path} ({:.1} KiB, model {})",
                container.size_bytes() as f64 / 1024.0,
                container.model_name
            );
            let server = Server::from_container(&cfg, &base, &container, prec, opts)?;
            // the server holds the dequantized+prepacked student; the
            // raw base weights must not stay resident for its lifetime
            drop(base);
            server
        }
        None => {
            println!("no --container: serving the unquantized {model} weights");
            let packed = PackedWeights::new(&cfg, base, prec);
            Server::start(cfg, packed, opts)
        }
    };
    println!(
        "prepacked : {:.1} KiB resident weight bytes ({} projections serving \
         straight from quantized codes)",
        server.packed_bytes() as f64 / 1024.0,
        server.coded_count()
    );

    let clients = args.usize_or("loadtest", 0)?;
    if clients > 0 {
        let per_client = args.usize_or("requests", 4)?;
        let mix = serve::LoadMix {
            generate_frac: args.f64_or("gen-frac", 0.0)?.clamp(0.0, 1.0),
            heavy_tail: args.bool("heavy-tail"),
            max_steps: args.usize_or("max-steps", 16)?.max(1),
        };
        let rep = serve::load_test(&server, clients, per_client, 7, &mix)?;
        rep.print();
        let stats = server.shutdown();
        println!(
            "served {} requests in {} batches ({} tokens, {} decode steps)",
            stats.requests, stats.batches, stats.tokens, stats.decode_steps
        );
        return Ok(());
    }

    let open_rps = args.f64_or("open-rps", 0.0)?;
    if open_rps > 0.0 {
        let secs = args.f64_or("duration-s", 2.0)?.max(0.1);
        let duration = std::time::Duration::from_secs_f64(secs);
        let rep = serve::load_test_open(&server, open_rps, duration, 7)?;
        rep.print();
        let stats = server.shutdown();
        println!(
            "served {} requests in {} batches ({} shed)",
            stats.requests, stats.batches, stats.shed
        );
        return Ok(());
    }
    serve_tcp(server, &args.str_or("addr", "127.0.0.1:7878"))
}

/// Install a SIGINT handler that sets (and never clears) a stop flag,
/// so `serve` can drain in-flight requests instead of dying mid-write.
#[cfg(unix)]
fn install_sigint_flag() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::{AtomicBool, Ordering};
    static STOP: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_sigint(_sig: i32) {
        // async-signal-safe: nothing but one atomic store
        STOP.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    // SAFETY: registers an async-signal-safe handler (a single atomic
    // store, no allocation or locking) for SIGINT through the libc
    // `signal` entry point; both the handler and the flag are 'static.
    unsafe {
        signal(SIGINT, on_sigint);
    }
    &STOP
}

#[cfg(not(unix))]
fn install_sigint_flag() -> &'static std::sync::atomic::AtomicBool {
    // no signal wiring: serve runs until the process is killed
    static STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
    &STOP
}

fn serve_tcp(server: Server, addr: &str) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    let opts = reactor::ReactorOpts::default();
    println!(
        "listening on {addr} (line-delimited JSON; max {} conns, idle {:?}, ^C drains)",
        opts.max_conns, opts.idle
    );
    let server = std::sync::Arc::new(server);
    let stop = install_sigint_flag();
    reactor::serve(&server, &listener, &opts, stop)?;
    let stats = server.stats();
    println!(
        "drained; served {} requests in {} batches ({} shed, {} cancelled)",
        stats.requests, stats.batches, stats.shed, stats.gen_cancelled
    );
    Ok(())
}

/// Component sweep at one rate: which §4 corrections help (debugging /
/// ablation aid; `repro ablate` is the paper-shaped version).
fn cmd_sweep(args: &Args) -> Result<()> {
    let ctx = Ctx::new(true, !args.bool("no-engine"))?;
    let rate = args.f64_or("rate", 1.5)?;
    let model = args.str_or("model", "picollama_s");
    let (cfg, teacher) = ctx.load_model(&model)?;
    let wiki = ctx.load_corpus("wiki")?;
    let windows = wiki.eval_windows(24, cfg.ctx, 1234);
    println!("{:<34} {:>9} {:>10}", "variant", "avg bits", "wiki PPL");
    let variants: Vec<(&str, Box<dyn Fn(&mut watersic::coordinator::PipelineOpts)>)> = vec![
        ("plain (no corrections)", Box::new(|o: &mut watersic::coordinator::PipelineOpts| {
            o.drift = false; o.residual = false; o.attn_weighted = false;
            o.quant.lmmse = false; o.quant.rescalers = false;
        })),
        ("+lmmse", Box::new(|o| { o.drift=false; o.residual=false; o.attn_weighted=false; o.quant.rescalers=false; })),
        ("+lmmse+rescalers", Box::new(|o| { o.drift=false; o.residual=false; o.attn_weighted=false; })),
        ("+drift", Box::new(|o| { o.residual=false; o.attn_weighted=false; })),
        ("+drift+residual", Box::new(|o| { o.attn_weighted=false; })),
        ("+drift+residual+attn (default)", Box::new(|_| {})),
        ("default, damping 0.01", Box::new(|o| { o.quant.damping = 0.01; })),
        ("default, damping 0.03", Box::new(|o| { o.quant.damping = 0.03; })),
        ("default, damping 0.1", Box::new(|o| { o.quant.damping = 0.1; })),
        ("damping 0.01, no drift", Box::new(|o| { o.quant.damping = 0.01; o.drift=false; o.residual=false; o.attn_weighted=false; })),
        ("default+mixing", Box::new(|o| { o.mixing = true; o.mixing_iters = 4; })),
        ("damping 0.01 + mixing", Box::new(|o| { o.quant.damping = 0.01; o.mixing = true; o.mixing_iters = 4; })),
    ];
    for (label, tweak) in variants {
        let mut o = experiments::llm::pipeline_opts(&ctx, Algo::WaterSic, rate, false);
        tweak(&mut o);
        let qm = quantize_model(&cfg, &teacher, &wiki, &o, ctx.engine.as_ref())?;
        let ppl = watersic::eval::perplexity_native(&cfg, &qm.student, &windows);
        println!("{:<34} {:>9.3} {:>10.3}", label, qm.report.avg_rate, ppl);
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let ctx = Ctx::new(true, !args.bool("no-engine"))?;
    let Some(engine) = &ctx.engine else {
        bail!("selftest needs the PJRT engine (artifacts + libxla)");
    };
    println!("platform: {}", engine.platform());
    println!("native kernel precision: {}", engine.precision().name());

    // 1. ZSIC artifact vs native oracle on a real shape
    let (a, n) = (64, 64);
    let mut rng = watersic::util::rng::Rng::new(5);
    let w = watersic::linalg::Mat::from_fn(a, n, |_, _| rng.gaussian());
    let sigma = watersic::quant::waterfilling::ar1_sigma(n, 0.8);
    let l = watersic::linalg::chol::cholesky(&sigma)?;
    let y = watersic::linalg::gemm::matmul(&w, &l);
    let alphas = watersic::quant::zsic::watersic_alphas(&l, 0.3);
    for lmmse in [false, true] {
        let native = watersic::quant::zsic::zsic(&y, &l, &alphas, lmmse, None);
        let art = engine.run_zsic(
            watersic::runtime::ZsicArtifact { a, n, lmmse },
            &y,
            &l,
            &alphas,
        )?;
        let mismatches = native
            .z
            .iter()
            .zip(&art.z)
            .filter(|(x, y)| x != y)
            .count();
        println!(
            "zsic {a}x{n} lmmse={lmmse}: {mismatches}/{} code mismatches \
             (f32 artifact vs f64 native)",
            a * n
        );
        anyhow::ensure!(
            (mismatches as f64) < 0.005 * (a * n) as f64,
            "too many mismatches"
        );
    }

    // 2. forward artifact vs native forward on the trained model
    let (cfg, weights) = ctx.load_model("picollama_s")?;
    let corpus = ctx.load_corpus("wiki")?;
    let windows = corpus.eval_windows(8, cfg.ctx, 77);
    let mut toks = Vec::new();
    for (i, _) in &windows {
        toks.extend_from_slice(i);
    }
    let rt = engine.run_forward(&cfg, &weights, &toks, 8)?;
    let nat = watersic::model::transformer::forward(
        &cfg,
        &weights,
        &toks,
        8,
        cfg.ctx,
        &watersic::model::transformer::ForwardOpts::default(),
    )
    .logits;
    let mut max_rel = 0.0f64;
    for i in 0..rt.data.len() {
        let denom = nat.data[i].abs().max(1.0);
        max_rel = max_rel.max((rt.data[i] - nat.data[i]).abs() / denom);
    }
    println!("forward picollama_s: max rel deviation {max_rel:.3e}");
    anyhow::ensure!(max_rel < 5e-3, "forward mismatch too large");
    println!("selftest OK");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = watersic::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        bail!("no manifest — run `make artifacts`");
    }
    let j = watersic::util::json::Json::parse(&std::fs::read_to_string(manifest)?)?;
    for (name, meta) in j.req("models")?.as_obj()? {
        println!(
            "model {name}: {} params, BF16 wiki PPL {:.3}, web PPL {:.3}",
            meta.req("n_params")?.as_usize()?,
            meta.req("bf16_ppl_wiki")?.as_f64()?,
            meta.req("bf16_ppl_web")?.as_f64()?
        );
    }
    let shapes = j.req("zsic_shapes")?.as_arr()?;
    println!("zsic artifact shapes: {}", shapes.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    /// The USAGE text and the `util::env` knob registry may never
    /// drift: every registered knob must be documented here, and every
    /// `WATERSIC_*` name the text mentions must be a registered knob
    /// (`xtask lint` additionally pins the registry as the only read
    /// path in the tree).
    #[test]
    fn usage_documents_exactly_the_registered_knobs() {
        for k in watersic::util::env::KNOBS {
            assert!(super::USAGE.contains(k.name), "USAGE missing {}", k.name);
        }
        for token in super::USAGE.split(|c: char| !(c.is_ascii_alphanumeric() || c == '_')) {
            // the bare prefix (as in the phrase "every WATERSIC_*
            // knob") names the family, not a knob
            if token.starts_with("WATERSIC_") && token != "WATERSIC_" {
                assert!(
                    watersic::util::env::KNOBS.iter().any(|k| k.name == token),
                    "USAGE mentions unregistered knob {token}"
                );
            }
        }
    }
}
