//! Evaluation harness: perplexity / bits-per-byte (native and PJRT
//! paths), KL divergence to the BF16 teacher, the Fig. 11 Gaussianity
//! study, and the zero-shot-style probe suite standing in for Table 17.

use anyhow::Result;

use crate::calib::corpus::Corpus;
use crate::linalg::stats::{ks_gaussian, ks_laplace};
use crate::linalg::Mat;
use crate::model::transformer::{cross_entropy, forward, kl_divergence, ForwardOpts};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::runtime::Engine;

/// Teacher-forced perplexity over evaluation windows (native path).
pub fn perplexity_native(
    cfg: &ModelConfig,
    w: &Weights,
    windows: &[(Vec<i32>, Vec<i32>)],
) -> f64 {
    let mut total = 0.0;
    let mut count = 0usize;
    for chunk in windows.chunks(4) {
        let b = chunk.len();
        let mut toks = Vec::with_capacity(b * cfg.ctx);
        let mut tgts = Vec::with_capacity(b * cfg.ctx);
        for (i, t) in chunk {
            toks.extend_from_slice(i);
            tgts.extend_from_slice(t);
        }
        let out = forward(cfg, w, &toks, b, cfg.ctx, &ForwardOpts::default());
        total += cross_entropy(&out.logits, &tgts) * (b * cfg.ctx) as f64;
        count += b * cfg.ctx;
    }
    (total / count as f64).exp()
}

/// Perplexity via the AOT forward artifact (production path; batch is
/// fixed by the export).  Windows beyond a multiple of the batch are
/// dropped.
pub fn perplexity_runtime(
    engine: &Engine,
    cfg: &ModelConfig,
    w: &Weights,
    windows: &[(Vec<i32>, Vec<i32>)],
    batch: usize,
) -> Result<f64> {
    let mut total = 0.0;
    let mut count = 0usize;
    for chunk in windows.chunks(batch) {
        if chunk.len() < batch {
            break;
        }
        let mut toks = Vec::with_capacity(batch * cfg.ctx);
        let mut tgts = Vec::with_capacity(batch * cfg.ctx);
        for (i, t) in chunk {
            toks.extend_from_slice(i);
            tgts.extend_from_slice(t);
        }
        let logits = engine.run_forward(cfg, w, &toks, batch)?;
        total += cross_entropy(&logits, &tgts) * (batch * cfg.ctx) as f64;
        count += batch * cfg.ctx;
    }
    anyhow::ensure!(count > 0, "no full batches to evaluate");
    Ok((total / count as f64).exp())
}

/// Bits-per-byte from perplexity (byte-level model): log₂ PPL.
pub fn bits_per_byte(ppl: f64) -> f64 {
    ppl.log2()
}

/// Mean KL(P_teacher ‖ P_student) in nats over evaluation windows.
pub fn kl_to_teacher(
    cfg: &ModelConfig,
    teacher: &Weights,
    student: &Weights,
    windows: &[(Vec<i32>, Vec<i32>)],
) -> f64 {
    let mut total = 0.0;
    let mut batches = 0usize;
    for chunk in windows.chunks(4) {
        let b = chunk.len();
        let mut toks = Vec::with_capacity(b * cfg.ctx);
        for (i, _) in chunk {
            toks.extend_from_slice(i);
        }
        let tl = forward(cfg, teacher, &toks, b, cfg.ctx, &ForwardOpts::default()).logits;
        let sl = forward(cfg, student, &toks, b, cfg.ctx, &ForwardOpts::default()).logits;
        total += kl_divergence(&tl, &sl);
        batches += 1;
    }
    total / batches.max(1) as f64
}

/// Fig. 11: KS distance of each quantizable matrix's entries to its
/// best-fit Gaussian and Laplace, grouped by layer type.
pub fn gaussianity_report(
    cfg: &ModelConfig,
    w: &Weights,
) -> Vec<(String, f64, f64, bool)> {
    let mut by_type: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>, usize, usize)> =
        std::collections::BTreeMap::new();
    for name in &cfg.quantizable {
        let short = name.rsplit('.').next().unwrap().to_string();
        let m = w.get(name);
        let kg = ks_gaussian(&m.data);
        let kl = ks_laplace(&m.data);
        let e = by_type.entry(short).or_default();
        e.0.push(kg);
        e.1.push(kl);
        if kg <= kl {
            e.2 += 1; // Gaussian preferred
        }
        e.3 += 1;
    }
    by_type
        .into_iter()
        .map(|(ty, (kg, kl, pref, total))| {
            (
                ty,
                kg.iter().sum::<f64>() / kg.len() as f64,
                kl.iter().sum::<f64>() / kl.len() as f64,
                2 * pref >= total,
            )
        })
        .collect()
}

/// Zero-shot-style probe suite (Table 17/18 analog): next-byte top-1
/// accuracy overall, on digit positions, on post-punctuation word
/// starts, and on whitespace — four "tasks" with distinct difficulty.
#[derive(Clone, Debug, Default)]
pub struct ProbeScores {
    pub top1: f64,
    pub digits: f64,
    pub word_start: f64,
    pub whitespace: f64,
}

pub fn probe_suite(
    cfg: &ModelConfig,
    w: &Weights,
    windows: &[(Vec<i32>, Vec<i32>)],
) -> ProbeScores {
    let mut hits = [0usize; 4];
    let mut tries = [0usize; 4];
    for chunk in windows.chunks(4) {
        let b = chunk.len();
        let mut toks = Vec::new();
        let mut tgts = Vec::new();
        for (i, t) in chunk {
            toks.extend_from_slice(i);
            tgts.extend_from_slice(t);
        }
        let logits = forward(cfg, w, &toks, b, cfg.ctx, &ForwardOpts::default()).logits;
        for r in 0..logits.rows {
            let row = logits.row(r);
            let pred = (0..cfg.vocab)
                .max_by(|&a, &b| row[a].total_cmp(&row[b]))
                .unwrap() as i32;
            let target = tgts[r];
            let prev = toks[r];
            let hit = (pred == target) as usize;
            hits[0] += hit;
            tries[0] += 1;
            let tb = target as u8;
            if tb.is_ascii_digit() {
                hits[1] += hit;
                tries[1] += 1;
            }
            if (prev as u8) == b' ' && (tb as char).is_ascii_alphabetic() {
                hits[2] += hit;
                tries[2] += 1;
            }
            if tb == b' ' || tb == b'\n' {
                hits[3] += hit;
                tries[3] += 1;
            }
        }
    }
    let frac = |i: usize| hits[i] as f64 / tries[i].max(1) as f64;
    ProbeScores {
        top1: frac(0),
        digits: frac(1),
        word_start: frac(2),
        whitespace: frac(3),
    }
}

/// Compressed-size accounting for Fig. 1: bits of all quantized streams
/// plus 16-bit scalars, over the *whole* model (unquantized embeddings /
/// head / norms counted at 16 bits as the paper does for BF16 storage).
pub fn compressed_size_bits(
    cfg: &ModelConfig,
    quantized_bits: f64,
    quantized_params: usize,
) -> f64 {
    let residual_params = cfg.n_params - quantized_params;
    quantized_bits + residual_params as f64 * 16.0
}

pub fn eval_windows_for(
    corpus: &Corpus,
    cfg: &ModelConfig,
    count: usize,
    seed: u64,
) -> Vec<(Vec<i32>, Vec<i32>)> {
    corpus.eval_windows(count, cfg.ctx, seed)
}

pub fn _mat_hint() -> Mat {
    Mat::zeros(0, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (ModelConfig, Weights, Vec<(Vec<i32>, Vec<i32>)>) {
        let cfg = ModelConfig::tiny_test();
        let w = Weights::random(&cfg, 3);
        let mut rng = Rng::new(1);
        let windows: Vec<(Vec<i32>, Vec<i32>)> = (0..6)
            .map(|_| {
                let i: Vec<i32> =
                    (0..cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();
                let t: Vec<i32> =
                    (0..cfg.ctx).map(|_| rng.below(cfg.vocab) as i32).collect();
                (i, t)
            })
            .collect();
        (cfg, w, windows)
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let (cfg, w, windows) = setup();
        let ppl = perplexity_native(&cfg, &w, &windows);
        // untrained model with random targets: PPL ≈ vocab
        assert!(ppl > cfg.vocab as f64 * 0.3 && ppl < cfg.vocab as f64 * 3.0,
                "ppl {ppl}");
        assert!((bits_per_byte(ppl) - ppl.log2()).abs() < 1e-12);
    }

    #[test]
    fn kl_is_zero_for_same_model_positive_otherwise() {
        let (cfg, w, windows) = setup();
        assert!(kl_to_teacher(&cfg, &w, &w, &windows[..2]).abs() < 1e-12);
        let w2 = Weights::random(&cfg, 99);
        assert!(kl_to_teacher(&cfg, &w, &w2, &windows[..2]) > 0.0);
    }

    #[test]
    fn gaussianity_report_shapes() {
        let (cfg, w, _) = setup();
        let rep = gaussianity_report(&cfg, &w);
        assert_eq!(rep.len(), 7); // w1 w2 w3 wk wo wq wv
        for (_ty, kg, kl, _pref) in &rep {
            assert!(*kg >= 0.0 && *kg <= 1.0);
            assert!(*kl >= 0.0 && *kl <= 1.0);
        }
        // random Gaussian init → Gaussian fit preferred
        let gauss_pref = rep.iter().filter(|r| r.3).count();
        assert!(gauss_pref >= 5, "{gauss_pref}/7 types preferred Gaussian");
    }

    #[test]
    fn probe_suite_in_unit_range() {
        let (cfg, w, windows) = setup();
        let p = probe_suite(&cfg, &w, &windows[..2]);
        for v in [p.top1, p.digits, p.word_start, p.whitespace] {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn compressed_size_accounting() {
        let mut cfg = ModelConfig::tiny_test();
        cfg.n_params = 1000;
        let bits = compressed_size_bits(&cfg, 2_000.0, 800);
        assert_eq!(bits, 2_000.0 + 200.0 * 16.0);
    }
}
