//! Packed, cache-blocked matrix kernels.  This is an L3 hot path
//! (covariance accumulation, drift statistics, rescaler objectives,
//! GPTQ/ZSIC panel updates), so the dense products run through a
//! BLIS-style three-level blocking scheme:
//!
//! * `KC`×`NC` panels of B and `MC`×`KC` blocks of A are **packed**
//!   into contiguous buffers laid out exactly as the micro-kernel
//!   consumes them (A in `MR`-row column-interleaved panels, B in
//!   `NR`-column row-interleaved panels), so the inner loop is pure
//!   sequential loads;
//! * an unrolled `MR`×`NR` = 4×8 register-tile **micro-kernel**
//!   accumulates into 32 scalar f64 accumulators the compiler keeps in
//!   vector registers (autovectorizes to AVX/NEON without intrinsics);
//! * the `MC`-row blocks are distributed over the persistent thread
//!   pool (`util::threadpool`) with chunk stealing.
//!
//! Determinism: every C element is produced by exactly one micro-tile,
//! and the K reduction order (KC blocks ascending, k ascending inside)
//! is independent of the thread count — threaded and single-threaded
//! runs are bit-for-bit identical.
//!
//! Operand views are `Panel`s (base pointer + row stride + optional
//! transpose), so the same driver serves `matmul`, `matmul_nt`
//! (A·Bᵀ without materializing the transpose), `gram` (Aᵀ·A by
//! symmetric blocks), the covariance accumulators (C += XᵀY), and the
//! ZSIC deferred rank-B panel update (C -= S·L on strided views).

use std::sync::atomic::{AtomicPtr, Ordering};

use super::Mat;
use crate::util::threadpool::{default_threads, parallel_ranges};

/// Register tile: MR×NR accumulators (MR is hard-wired into the
/// micro-kernel unroll).
const MR: usize = 4;
const NR: usize = 8;
/// Rows of A per cache block (multiple of MR; A block = MC×KC ≈ 128 KiB
/// — L2-resident).
const MC: usize = 64;
/// K extent per packing pass (B panel = KC×NC ≈ 2 MiB — L3-resident).
const KC: usize = 256;
/// Columns of B per packing pass.
const NC: usize = 1024;
/// Below this m·k·n the packing overhead dominates — use the simple
/// serial kernel.
const SMALL_GEMM: usize = 1 << 14;

const _: () = assert!(MC % MR == 0, "MC must be a multiple of MR");

/// Borrowed view of an m×k operand: element (i, j) lives at
/// `data[i*ld + j]`, or at `data[j*ld + i]` when `trans` is set (the
/// view then presents the transpose of the underlying storage).
#[derive(Clone, Copy)]
struct Panel<'a> {
    data: &'a [f64],
    /// operator rows (after any transpose)
    rows: usize,
    /// operator cols (after any transpose)
    cols: usize,
    /// row stride of the underlying storage
    ld: usize,
    trans: bool,
}

impl<'a> Panel<'a> {
    fn normal(m: &'a Mat) -> Panel<'a> {
        Panel {
            data: &m.data,
            rows: m.rows,
            cols: m.cols,
            ld: m.cols,
            trans: false,
        }
    }

    fn transposed(m: &'a Mat) -> Panel<'a> {
        Panel {
            data: &m.data,
            rows: m.cols,
            cols: m.rows,
            ld: m.cols,
            trans: true,
        }
    }

    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f64 {
        if self.trans {
            self.data[j * self.ld + i]
        } else {
            self.data[i * self.ld + j]
        }
    }
}

/// 4×8 register-tile micro-kernel over packed panels.
///
/// `ap` holds `kc` steps of MR interleaved A values, `bp` holds `kc`
/// steps of NR interleaved B values.  The full MR×NR accumulator is
/// always computed (panels are zero-padded); only the `mr`×`nr` valid
/// corner is written back.
///
/// # Safety
/// `ap`/`bp` must be valid for `kc*MR` / `kc*NR` reads; `c` must be
/// valid for the `mr`×`nr` tile at row stride `ldc`, with exclusive
/// access.
#[inline(always)]
unsafe fn microkernel(
    kc: usize,
    ap: *const f64,
    bp: *const f64,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    let mut acc = [[0.0f64; NR]; MR];
    for kk in 0..kc {
        let apk = ap.add(kk * MR);
        let bpk = bp.add(kk * NR);
        let a0 = *apk;
        let a1 = *apk.add(1);
        let a2 = *apk.add(2);
        let a3 = *apk.add(3);
        for cc in 0..NR {
            let bv = *bpk.add(cc);
            acc[0][cc] += a0 * bv;
            acc[1][cc] += a1 * bv;
            acc[2][cc] += a2 * bv;
            acc[3][cc] += a3 * bv;
        }
    }
    for r in 0..mr {
        let crow = c.add(r * ldc);
        for cc in 0..nr {
            let v = alpha * acc[r][cc];
            let dst = crow.add(cc);
            if store {
                *dst = v;
            } else {
                *dst += v;
            }
        }
    }
}

/// Blocked packed GEMM: C ⟵ α·A·B (`accumulate = false`) or
/// C += α·A·B (`accumulate = true`), with C row-major at stride `ldc`.
///
/// # Safety
/// `c` must be valid for `(m-1)*ldc + n` elements with exclusive
/// access for the duration of the call.
unsafe fn gemm_driver(
    a: Panel,
    b: Panel,
    c: *mut f64,
    ldc: usize,
    accumulate: bool,
    alpha: f64,
    threads: usize,
) {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    debug_assert_eq!(b.rows, k, "gemm driver inner-dim mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            for i in 0..m {
                std::slice::from_raw_parts_mut(c.add(i * ldc), n).fill(0.0);
            }
        }
        return;
    }

    let cshared = AtomicPtr::new(c);
    let nblocks = m.div_ceil(MC);
    // one B-pack buffer reused across every (jc, pc) pass — the pack
    // loops overwrite every slot they use (padding written explicitly)
    let mut bpack = vec![0.0f64; (NC.min(n).div_ceil(NR) * NR) * KC.min(k)];
    for jc0 in (0..n).step_by(NC) {
        let nc_eff = NC.min(n - jc0);
        let ncr = nc_eff.div_ceil(NR) * NR;
        for pc0 in (0..k).step_by(KC) {
            let kc_eff = KC.min(k - pc0);
            let store = pc0 == 0 && !accumulate;

            // ---- pack B: ncr/NR panels of NR interleaved columns
            {
                let bp = &mut bpack[..ncr * kc_eff];
                for q in 0..ncr / NR {
                    let joff = jc0 + q * NR;
                    let dst0 = q * NR * kc_eff;
                    for kk in 0..kc_eff {
                        let dst = dst0 + kk * NR;
                        for cc in 0..NR {
                            let j = joff + cc;
                            bp[dst + cc] = if j < jc0 + nc_eff {
                                b.at(pc0 + kk, j)
                            } else {
                                0.0
                            };
                        }
                    }
                }
            }

            let bpack_ref = &bpack[..ncr * kc_eff];
            parallel_ranges(nblocks, threads, |range| {
                let cbase = cshared.load(Ordering::Relaxed);
                let mut apack = vec![0.0f64; MC * kc_eff];
                for blk in range {
                    let ic0 = blk * MC;
                    let mc_eff = MC.min(m - ic0);
                    let mcr = mc_eff.div_ceil(MR) * MR;

                    // ---- pack A block: mcr/MR panels of MR rows
                    for p in 0..mcr / MR {
                        let ioff = ic0 + p * MR;
                        let dst0 = p * MR * kc_eff;
                        for kk in 0..kc_eff {
                            let dst = dst0 + kk * MR;
                            for r in 0..MR {
                                let i = ioff + r;
                                apack[dst + r] = if i < ic0 + mc_eff {
                                    a.at(i, pc0 + kk)
                                } else {
                                    0.0
                                };
                            }
                        }
                    }

                    // ---- micro-tile sweep
                    for q in 0..ncr / NR {
                        let j0 = q * NR;
                        let nr_eff = NR.min(nc_eff - j0);
                        for p in 0..mcr / MR {
                            let i0 = p * MR;
                            let mr_eff = MR.min(mc_eff - i0);
                            // SAFETY: pack offsets are in range by
                            // construction; C tiles of distinct blocks
                            // are disjoint row ranges.
                            unsafe {
                                let ap = apack.as_ptr().add(p * MR * kc_eff);
                                let bp = bpack_ref.as_ptr().add(q * NR * kc_eff);
                                let ctile =
                                    cbase.add((ic0 + i0) * ldc + jc0 + j0);
                                microkernel(
                                    kc_eff, ap, bp, ctile, ldc, mr_eff, nr_eff,
                                    store, alpha,
                                );
                            }
                        }
                    }
                }
            });
        }
    }
}

fn threads_for(work: usize) -> usize {
    if work > 1 << 18 {
        default_threads()
    } else {
        1
    }
}

/// Serial fallback for small products (ikj order, C row hot).
fn matmul_small_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let n = b.cols;
    let k = a.cols;
    for i in 0..a.rows {
        let crow = c.row_mut(i);
        crow.fill(0.0);
        let arow = a.row(i);
        for kk in 0..k {
            let aik = arow[kk];
            let brow = b.row(kk);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Sampled overflow check (debug builds only): a ±∞ in C means the
/// product overflowed somewhere.  O(16) instead of the O(mn) full scan
/// the seed kernel paid on every call.
fn debug_check_overflow(c: &Mat) {
    if cfg!(debug_assertions) && !c.data.is_empty() {
        let step = (c.data.len() / 16).max(1);
        for idx in (0..c.data.len()).step_by(step) {
            debug_assert!(
                !c.data[idx].is_infinite(),
                "gemm output overflowed to ±∞ at flat index {idx}"
            );
        }
    }
}

/// C = A · B
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B with an explicit thread count — the threaded and
/// single-threaded results are bit-for-bit identical (see module docs);
/// exposed for determinism tests and tuning.
pub fn matmul_with_threads(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into_threads(a, b, &mut c, threads);
    c
}

/// C = A · B (C pre-allocated, overwritten).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let threads = threads_for(a.rows * b.cols * a.cols);
    matmul_into_threads(a, b, c, threads);
}

fn matmul_into_threads(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    if a.rows * b.cols * a.cols <= SMALL_GEMM {
        matmul_small_into(a, b, c);
    } else {
        let ldc = c.cols;
        // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
        unsafe {
            gemm_driver(
                Panel::normal(a),
                Panel::normal(b),
                c.data.as_mut_ptr(),
                ldc,
                false,
                1.0,
                threads,
            );
        }
    }
    debug_check_overflow(c);
}

/// C = A · Bᵀ without materializing the transpose.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch");
    let n = b.rows;
    let mut c = Mat::zeros(a.rows, n);
    if a.rows * n * a.cols <= SMALL_GEMM {
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] = super::dot(arow, b.row(j));
            }
        }
    } else {
        let threads = threads_for(a.rows * n * a.cols);
        // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
        unsafe {
            gemm_driver(
                Panel::normal(a),
                Panel::transposed(b),
                c.data.as_mut_ptr(),
                n,
                false,
                1.0,
                threads,
            );
        }
    }
    debug_check_overflow(&c);
    c
}

/// C += Xᵀ · Y (cross-moment accumulation; X is r×m, Y is r×n, C is
/// m×n).  The covariance accumulators stream panels through this.
pub fn matmul_tn_acc(x: &Mat, y: &Mat, c: &mut Mat) {
    assert_eq!(x.rows, y.rows, "gemm_tn shape mismatch");
    assert_eq!((c.rows, c.cols), (x.cols, y.cols));
    let (m, k, n) = (x.cols, x.rows, y.cols);
    if m * k * n <= SMALL_GEMM {
        for r in 0..k {
            let xr = x.row(r);
            let yr = y.row(r);
            for i in 0..m {
                let xi = xr[i];
                if xi == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for j in 0..n {
                    crow[j] += xi * yr[j];
                }
            }
        }
        return;
    }
    let threads = threads_for(m * k * n);
    // SAFETY: c.data is exactly m×n and exclusively borrowed.
    unsafe {
        gemm_driver(
            Panel::transposed(x),
            Panel::normal(y),
            c.data.as_mut_ptr(),
            n,
            true,
            1.0,
            threads,
        );
    }
}

/// C = Aᵀ · A (Gram matrix), exploiting symmetry: only upper-triangle
/// blocks are computed (in parallel), the strict lower triangle is
/// mirrored.  The covariance accumulator reduces to this on activation
/// panels.
pub fn gram(a: &Mat) -> Mat {
    gram_with_threads(a, threads_for(a.rows * a.cols * a.cols))
}

/// [`gram`] with an explicit thread count (bit-for-bit identical across
/// thread counts; exposed for determinism tests and tuning).
pub fn gram_with_threads(a: &Mat, threads: usize) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    syrk_upper(a, &mut c, threads);
    mirror_lower(&mut c);
    c
}

/// C += Aᵀ · A for a symmetric accumulator.  C must be exactly
/// symmetric on entry (e.g. zero, or only ever updated through this
/// function): the update computes upper-triangle blocks and mirrors,
/// which preserves exact symmetry.
pub fn gram_acc(a: &Mat, c: &mut Mat) {
    assert_eq!((c.rows, c.cols), (a.cols, a.cols), "gram_acc shape");
    syrk_upper(a, c, threads_for(a.rows * a.cols * a.cols));
    mirror_lower(c);
}

/// Accumulate the upper triangle (incl. diagonal blocks in full) of
/// Aᵀ·A into C.
fn syrk_upper(a: &Mat, c: &mut Mat, threads: usize) {
    let n = a.cols;
    let m = a.rows;
    if n == 0 || m == 0 {
        return;
    }
    if m * n * n <= SMALL_GEMM {
        // serial triangle, row-streaming
        for r in 0..m {
            let row = a.row(r);
            for i in 0..n {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for j in i..n {
                    crow[j] += xi * row[j];
                }
            }
        }
        return;
    }

    // output-block edge for the symmetric sweep
    const GB: usize = 64;
    let nb = n.div_ceil(GB);
    let pairs: Vec<(usize, usize)> = (0..nb)
        .flat_map(|i| (i..nb).map(move |j| (i, j)))
        .collect();
    let cptr = AtomicPtr::new(c.data.as_mut_ptr());
    let adata = &a.data;
    parallel_ranges(pairs.len(), threads, |range| {
        let base = cptr.load(Ordering::Relaxed);
        for t in range {
            let (bi, bj) = pairs[t];
            let i0 = bi * GB;
            let i1 = ((bi + 1) * GB).min(n);
            let j0 = bj * GB;
            let j1 = ((bj + 1) * GB).min(n);
            // C[i0..i1, j0..j1] += A[:, i0..i1]ᵀ · A[:, j0..j1]
            let at = Panel {
                data: &adata[i0..],
                rows: i1 - i0,
                cols: m,
                ld: n,
                trans: true,
            };
            let ap = Panel {
                data: &adata[j0..],
                rows: m,
                cols: j1 - j0,
                ld: n,
                trans: false,
            };
            // SAFETY: block (bi, bj) owns the disjoint C region
            // [i0..i1)×[j0..j1); serial inner driver (threads = 1).
            unsafe {
                gemm_driver(at, ap, base.add(i0 * n + j0), n, true, 1.0, 1);
            }
        }
    });
}

fn mirror_lower(c: &mut Mat) {
    for i in 1..c.rows {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

/// C += α · A·B over raw strided views (A is m×k at stride `a_ld`, B is
/// k×n at stride `b_ld`, C is m×n at stride `c_ld`).  Fused panel
/// update for the ZSIC/GPTQ deferred rank-B interference subtraction —
/// the α = −1 path replaces the per-element axpy sweep.
pub(crate) fn gemm_acc_strided(
    m: usize,
    k: usize,
    n: usize,
    a_data: &[f64],
    a_ld: usize,
    b_data: &[f64],
    b_ld: usize,
    c_data: &mut [f64],
    c_ld: usize,
    alpha: f64,
    threads: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(a_data.len() >= (m - 1) * a_ld + k);
    debug_assert!(b_data.len() >= (k - 1) * b_ld + n);
    debug_assert!(c_data.len() >= (m - 1) * c_ld + n);
    let ap = Panel {
        data: a_data,
        rows: m,
        cols: k,
        ld: a_ld,
        trans: false,
    };
    let bp = Panel {
        data: b_data,
        rows: k,
        cols: n,
        ld: b_ld,
        trans: false,
    };
    // SAFETY: extents checked above; c_data exclusively borrowed.
    unsafe {
        gemm_driver(ap, bp, c_data.as_mut_ptr(), c_ld, true, alpha, threads);
    }
}

/// y = M · x
pub fn matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.cols, x.len());
    (0..m.rows).map(|i| super::dot(m.row(i), x)).collect()
}

/// y = Mᵀ · x
pub fn matvec_t(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.rows, x.len());
    let mut y = vec![0.0; m.cols];
    for i in 0..m.rows {
        super::axpy(x[i], m.row(i), &mut y);
    }
    y
}

/// diag(A · B) without forming the product — Alg. 4 needs diagonals of
/// several m×m products where only the diagonal is used.
pub fn diag_of_product(a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols);
    (0..a.rows)
        .map(|i| {
            let mut s = 0.0;
            for k in 0..a.cols {
                s += a[(i, k)] * b[(k, i)];
            }
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 33, 9), (64, 64, 64), (1, 7, 1)] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.sub(&c0).max_abs() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_matches_naive_nondivisible_tiles() {
        // shapes straddling every tile edge: MR=4, NR=8, MC=64, KC=256
        let mut rng = Rng::new(41);
        for (m, k, n) in [
            (5, 70, 9),      // nothing divides
            (63, 65, 67),    // just under/over MC
            (129, 257, 33),  // crosses MC and KC boundaries
            (8, 600, 8),     // exact tile, K spans three KC blocks
            (66, 40, 1030),  // crosses the NC panel edge
        ] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.sub(&c0).max_abs() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_degenerate_shapes() {
        let mut rng = Rng::new(42);
        // empty result dimensions
        let a = Mat::zeros(0, 7);
        let b = randm(7, 5, &mut rng);
        let c = matmul(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 5));
        // empty inner dimension → exact zeros
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let c = matmul(&a, &b);
        assert!(c.data.iter().all(|&x| x == 0.0));
        // single row / single column
        let a = randm(1, 200, &mut rng);
        let b = randm(200, 100, &mut rng);
        assert!(matmul(&a, &b).sub(&naive(&a, &b)).max_abs() < 1e-9);
        let b1 = randm(200, 1, &mut rng);
        assert!(matmul(&a, &b1).sub(&naive(&a, &b1)).max_abs() < 1e-9);
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // same tile decomposition and K order regardless of thread
        // count ⇒ bit-for-bit equality, not just tolerance
        let mut rng = Rng::new(43);
        let a = randm(150, 170, &mut rng);
        let b = randm(170, 130, &mut rng);
        let c1 = matmul_with_threads(&a, &b, 1);
        let c8 = matmul_with_threads(&a, &b, 8);
        assert_eq!(c1.data, c8.data, "threaded gemm must be deterministic");
        let p = randm(300, 90, &mut rng);
        let g1 = gram_with_threads(&p, 1);
        let g8 = gram_with_threads(&p, 8);
        assert_eq!(g1.data, g8.data, "threaded gram must be deterministic");
    }

    #[test]
    fn matmul_nt_matches() {
        let mut rng = Rng::new(2);
        let a = randm(13, 21, &mut rng);
        let b = randm(8, 21, &mut rng);
        let c = matmul_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.sub(&c0).max_abs() < 1e-9);
        // large enough to hit the packed transposed-B path
        let a = randm(70, 90, &mut rng);
        let b = randm(110, 90, &mut rng);
        let c = matmul_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }

    #[test]
    fn gram_is_ata() {
        let mut rng = Rng::new(3);
        let a = randm(40, 12, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
        // symmetry
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn gram_packed_path_matches_and_is_symmetric() {
        // big enough for the blocked symmetric sweep, non-divisible n
        let mut rng = Rng::new(44);
        let a = randm(200, 70, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
        for i in 0..70 {
            for j in 0..70 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
        // and across the GB=64 block edge with >1 block in each dim
        let a = randm(150, 130, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
    }

    #[test]
    fn gram_acc_accumulates() {
        let mut rng = Rng::new(45);
        let a = randm(120, 40, &mut rng);
        let b = randm(80, 40, &mut rng);
        let mut acc = Mat::zeros(40, 40);
        gram_acc(&a, &mut acc);
        gram_acc(&b, &mut acc);
        let expect = naive(&a.transpose(), &a).add(&naive(&b.transpose(), &b));
        assert!(acc.sub(&expect).max_abs() < 1e-9);
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(acc[(i, j)], acc[(j, i)]);
            }
        }
    }

    #[test]
    fn matmul_tn_acc_matches() {
        let mut rng = Rng::new(46);
        for (r, m, n) in [(30, 6, 8), (120, 40, 50)] {
            let x = randm(r, m, &mut rng);
            let y = randm(r, n, &mut rng);
            let mut c = Mat::zeros(m, n);
            matmul_tn_acc(&x, &y, &mut c);
            matmul_tn_acc(&x, &y, &mut c); // accumulate twice
            let expect = naive(&x.transpose(), &y).scale(2.0);
            assert!(c.sub(&expect).max_abs() < 1e-9, "{r}x{m}x{n}");
        }
    }

    #[test]
    fn strided_acc_matches_axpy_reference() {
        // emulate the ZSIC deferred update: C[:, :blo] -= S · L-block
        let mut rng = Rng::new(47);
        let (a, bw, blo, ld) = (40, 16, 50, 64);
        let s = randm(a, ld, &mut rng); // only first bw cols used
        let l = randm(bw, blo, &mut rng);
        let mut c = randm(a, blo, &mut rng);
        let mut c_ref = c.clone();
        for r in 0..a {
            for k in 0..bw {
                let coeff = s[(r, k)];
                for j in 0..blo {
                    c_ref[(r, j)] -= coeff * l[(k, j)];
                }
            }
        }
        gemm_acc_strided(
            a, bw, blo, &s.data, ld, &l.data, blo, &mut c.data, blo, -1.0, 2,
        );
        assert!(c.sub(&c_ref).max_abs() < 1e-9);
    }

    #[test]
    fn matvec_both_ways() {
        let mut rng = Rng::new(4);
        let m = randm(6, 9, &mut rng);
        let x: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let y = matvec(&m, &x);
        let y0 = naive(&m, &Mat::from_vec(9, 1, x.clone()));
        for i in 0..6 {
            assert!((y[i] - y0[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let w = matvec_t(&m, &z);
        let w0 = naive(&m.transpose(), &Mat::from_vec(6, 1, z));
        for j in 0..9 {
            assert!((w[j] - w0[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn diag_of_product_matches() {
        let mut rng = Rng::new(5);
        let a = randm(7, 11, &mut rng);
        let b = randm(11, 7, &mut rng);
        let d = diag_of_product(&a, &b);
        let full = matmul(&a, &b);
        for i in 0..7 {
            assert!((d[i] - full[(i, i)]).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_path_consistent() {
        // big enough to trigger the threaded path
        let mut rng = Rng::new(6);
        let a = randm(128, 96, &mut rng);
        let b = randm(96, 80, &mut rng);
        let c = matmul(&a, &b);
        let c0 = naive(&a, &b);
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }
}
