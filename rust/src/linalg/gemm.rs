//! Blocked matrix multiplication and friends.  This is an L3 hot path
//! (covariance accumulation, drift statistics, rescaler objectives), so
//! the kernel is cache-blocked with an ikj inner order that keeps the
//! C row hot and lets the compiler autovectorize, and row-parallel
//! across threads.

use super::Mat;
use crate::util::threadpool::{default_threads, parallel_ranges};

const BLOCK_K: usize = 64;

/// C = A · B
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B (C pre-allocated, overwritten).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let n = b.cols;
    let k = a.cols;
    let threads = if a.rows * n * k > 1 << 18 {
        default_threads()
    } else {
        1
    };
    let cdata = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());
    parallel_ranges(a.rows, threads, |range| {
        let cptr = cdata.load(std::sync::atomic::Ordering::Relaxed);
        for i in range {
            // SAFETY: disjoint row ranges per thread.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.add(i * n), n) };
            crow.fill(0.0);
            let arow = a.row(i);
            for k0 in (0..k).step_by(BLOCK_K) {
                let k1 = (k0 + BLOCK_K).min(k);
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    });
    c
        .data
        .iter()
        .for_each(|x| debug_assert!(x.is_finite() || x.is_nan()));
}

/// C = A · Bᵀ without materializing the transpose.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    let n = b.rows;
    let threads = if a.rows * n * a.cols > 1 << 18 {
        default_threads()
    } else {
        1
    };
    let cdata = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());
    parallel_ranges(a.rows, threads, |range| {
        let cptr = cdata.load(std::sync::atomic::Ordering::Relaxed);
        for i in range {
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.add(i * n), n) };
            let arow = a.row(i);
            for j in 0..n {
                crow[j] = super::dot(arow, b.row(j));
            }
        }
    });
    c
}

/// C = Aᵀ · A (Gram matrix), exploiting symmetry.  The covariance
/// accumulator reduces to this on activation panels.
pub fn gram(a: &Mat) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    for r in 0..a.rows {
        let row = a.row(r);
        for i in 0..n {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in i..n {
                crow[j] += xi * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

/// y = M · x
pub fn matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.cols, x.len());
    (0..m.rows).map(|i| super::dot(m.row(i), x)).collect()
}

/// y = Mᵀ · x
pub fn matvec_t(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.rows, x.len());
    let mut y = vec![0.0; m.cols];
    for i in 0..m.rows {
        super::axpy(x[i], m.row(i), &mut y);
    }
    y
}

/// diag(A · B) without forming the product — Alg. 4 needs diagonals of
/// several m×m products where only the diagonal is used.
pub fn diag_of_product(a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols);
    (0..a.rows)
        .map(|i| {
            let mut s = 0.0;
            for k in 0..a.cols {
                s += a[(i, k)] * b[(k, i)];
            }
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 33, 9), (64, 64, 64), (1, 7, 1)] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.sub(&c0).max_abs() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_nt_matches() {
        let mut rng = Rng::new(2);
        let a = randm(13, 21, &mut rng);
        let b = randm(8, 21, &mut rng);
        let c = matmul_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }

    #[test]
    fn gram_is_ata() {
        let mut rng = Rng::new(3);
        let a = randm(40, 12, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
        // symmetry
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn matvec_both_ways() {
        let mut rng = Rng::new(4);
        let m = randm(6, 9, &mut rng);
        let x: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let y = matvec(&m, &x);
        let y0 = naive(&m, &Mat::from_vec(9, 1, x.clone()));
        for i in 0..6 {
            assert!((y[i] - y0[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let w = matvec_t(&m, &z);
        let w0 = naive(&m.transpose(), &Mat::from_vec(6, 1, z));
        for j in 0..9 {
            assert!((w[j] - w0[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn diag_of_product_matches() {
        let mut rng = Rng::new(5);
        let a = randm(7, 11, &mut rng);
        let b = randm(11, 7, &mut rng);
        let d = diag_of_product(&a, &b);
        let full = matmul(&a, &b);
        for i in 0..7 {
            assert!((d[i] - full[(i, i)]).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_path_consistent() {
        // big enough to trigger the threaded path
        let mut rng = Rng::new(6);
        let a = randm(128, 96, &mut rng);
        let b = randm(96, 80, &mut rng);
        let c = matmul(&a, &b);
        let c0 = naive(&a, &b);
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }
}
