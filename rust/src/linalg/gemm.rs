//! Packed, cache-blocked matrix kernels.  This is an L3 hot path
//! (covariance accumulation, drift statistics, rescaler objectives,
//! GPTQ/ZSIC panel updates), so the dense products run through a
//! BLIS-style three-level blocking scheme:
//!
//! * `KC`×`NC` panels of B and `MC`×`KC` blocks of A are **packed**
//!   into contiguous buffers laid out exactly as the micro-kernel
//!   consumes them (A in `MR`-row column-interleaved panels, B in
//!   `NR`-column row-interleaved panels), so the inner loop is pure
//!   sequential loads;
//! * an unrolled `MR`×`NR` register-tile **micro-kernel** accumulates
//!   into scalar or vector registers (4×8 for f64, 8×8 for f32 — the
//!   lanes double when the element halves);
//! * the `MC`-row blocks are distributed over the persistent thread
//!   pool (`util::threadpool`) with chunk stealing.
//!
//! # Precision
//!
//! The packed driver is generic over an [`Element`] (f64 or f32).  In
//! f32 mode the pack buffers and the micro-kernel run in f32 (double
//! the vector lanes, half the pack bandwidth) while C stays f64: each
//! micro-tile reduces one `KC` block in f32 registers and folds the
//! partial into the f64 accumulator, so cross-block accumulation is
//! always f64.  The `*_prec` entry points select the mode; consumers
//! that tolerate reduced precision (covariance/drift streaming, the
//! model forward) opt in through the `WATERSIC_PRECISION` engine
//! option ([`Precision::from_env`]), while the quantizer core stays
//! f64.  Products below `SMALL_GEMM` always use the serial f64 kernel
//! (packing overhead dominates), so f32 mode only changes packed-path
//! shapes.
//!
//! # Dispatch ladder
//!
//! Each element type owns a ladder of micro-kernels selected once per
//! process by [`simd_backend`]:
//!
//! * **avx2** (x86-64, via `is_x86_feature_detected!`): explicit
//!   256-bit intrinsics — 8 f32 / 4 f64 lanes per register;
//! * **neon** (aarch64, baseline feature — no runtime check needed):
//!   explicit 128-bit intrinsics;
//! * **scalar**: the unrolled register-tile loops the compiler
//!   autovectorizes for the build target's baseline features.
//!
//! Every rung uses separate mul + add (never FMA), keeping each
//! accumulator lane's reduction chain bit-identical across the ladder:
//! dispatch never changes a single output bit, only throughput.
//! `WATERSIC_SIMD=scalar` forces the fallback rung (AVX-512 is left
//! out: this tree grows in a container without a local toolchain, so
//! only rungs that are verifiable on stable Rust across both arches —
//! AVX2 and NEON — are wired in; see ROADMAP).
//!
//! Determinism: every C element is produced by exactly one micro-tile,
//! and the K reduction order (KC blocks ascending, k ascending inside)
//! is independent of the thread count — threaded and single-threaded
//! runs are bit-for-bit identical, in both precisions.
//!
//! Operand views are `Panel`s (base pointer + row stride + optional
//! transpose), so the same driver serves `matmul`, `matmul_nt`
//! (A·Bᵀ without materializing the transpose), `gram` (Aᵀ·A by
//! symmetric blocks), the covariance accumulators (C += XᵀY), and the
//! ZSIC deferred rank-B panel update (C -= S·L on strided views).

use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::OnceLock;

use super::Mat;
use crate::util::threadpool::{default_threads, parallel_ranges};

/// f64 register tile: MR×NR accumulators.
const MR_F64: usize = 4;
const NR_F64: usize = 8;
/// f32 register tile: lanes double, so the tile widens to 8×8.
const MR_F32: usize = 8;
const NR_F32: usize = 8;
/// Rows of A per cache block (multiple of every MR; A block = MC×KC ≈
/// 128 KiB — L2-resident).
const MC: usize = 64;
/// K extent per packing pass (B panel = KC×NC ≈ 2 MiB — L3-resident).
const KC: usize = 256;
/// Columns of B per packing pass.
const NC: usize = 1024;
/// Below this m·k·n the packing overhead dominates — use the simple
/// serial kernel.
const SMALL_GEMM: usize = 1 << 14;

const _: () = assert!(MC % MR_F64 == 0, "MC must be a multiple of f64 MR");
const _: () = assert!(MC % MR_F32 == 0, "MC must be a multiple of f32 MR");

/// Storage/compute precision of the packed kernel path.  C (and every
/// `Mat`) stays f64 in both modes; f32 selects f32 pack buffers and
/// micro-kernels with per-KC-block f64 accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F64,
    F32,
}

impl Precision {
    /// Engine-wide default from `WATERSIC_PRECISION={f32,f64}` (cached
    /// on first read; defaults to f64, warning on unrecognized values
    /// so a typo'd env never silently runs the wrong path).
    pub fn from_env() -> Precision {
        static CHOSEN: OnceLock<Precision> = OnceLock::new();
        *CHOSEN.get_or_init(|| {
            match crate::util::env::string("WATERSIC_PRECISION").as_deref() {
                Some("f32") | Some("F32") => Precision::F32,
                Some("f64") | Some("F64") | None => Precision::F64,
                Some(other) => {
                    eprintln!(
                        "[linalg] unrecognized WATERSIC_PRECISION={other:?} \
                         (expected f32 or f64); using f64"
                    );
                    Precision::F64
                }
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Which micro-kernel rung the dispatch ladder selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// Portable unrolled loops (autovectorized at the build target's
    /// baseline features).  Bit-identical to every SIMD rung.
    Scalar,
    /// Explicit 256-bit AVX2 kernels (x86-64, runtime-detected).
    Avx2,
    /// Explicit 128-bit NEON kernels (aarch64 baseline).
    Neon,
}

impl SimdBackend {
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
            SimdBackend::Neon => "neon",
        }
    }
}

#[allow(unreachable_code)]
fn detect_backend() -> SimdBackend {
    // Miri has no SIMD intrinsics: force the scalar rung so the tagged
    // small-shape tests can interpret the kernels end to end.
    if cfg!(miri) {
        return SimdBackend::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdBackend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline — no runtime check.
        return SimdBackend::Neon;
    }
    SimdBackend::Scalar
}

/// The process-wide kernel backend (cached on first call).  Honors
/// `WATERSIC_SIMD=scalar` to force the fallback rung; anything else
/// takes the best runtime-detected rung (unrecognized values warn —
/// features the CPU lacks cannot be forced on).
pub fn simd_backend() -> SimdBackend {
    static CHOSEN: OnceLock<SimdBackend> = OnceLock::new();
    *CHOSEN.get_or_init(|| {
        match crate::util::env::string("WATERSIC_SIMD").as_deref() {
            Some("scalar") => return SimdBackend::Scalar,
            Some(other) => eprintln!(
                "[linalg] unrecognized WATERSIC_SIMD={other:?} \
                 (only \"scalar\" can be forced); using runtime detection"
            ),
            None => {}
        }
        detect_backend()
    })
}

/// Element of the packed panels.  Implementations own their register
/// tile geometry and micro-kernel dispatch ladder; the blocked driver
/// is generic over this.
trait Element: Copy + Send + Sync + 'static {
    /// Register-tile rows (interleave factor of packed A panels).
    const MR: usize;
    /// Register-tile cols (interleave factor of packed B panels).
    const NR: usize;
    const ZERO: Self;
    fn from_f64(x: f64) -> Self;

    /// MR×NR micro-kernel over packed panels, writing α·(A·B) for one
    /// KC block into the f64 C tile (`store` overwrites, else adds).
    ///
    /// # Safety
    /// `ap`/`bp` must be valid for `kc*MR` / `kc*NR` reads; `c` must be
    /// valid for the `mr`×`nr` tile at row stride `ldc`, with exclusive
    /// access.
    #[allow(clippy::too_many_arguments)]
    unsafe fn microkernel(
        backend: SimdBackend,
        kc: usize,
        ap: *const Self,
        bp: *const Self,
        c: *mut f64,
        ldc: usize,
        mr: usize,
        nr: usize,
        store: bool,
        alpha: f64,
    );
}

impl Element for f64 {
    const MR: usize = MR_F64;
    const NR: usize = NR_F64;
    const ZERO: f64 = 0.0;

    #[inline(always)]
    fn from_f64(x: f64) -> f64 {
        x
    }

    /// # Safety
    /// See [`Element::microkernel`].
    #[inline(always)]
    unsafe fn microkernel(
        backend: SimdBackend,
        kc: usize,
        ap: *const f64,
        bp: *const f64,
        c: *mut f64,
        ldc: usize,
        mr: usize,
        nr: usize,
        store: bool,
        alpha: f64,
    ) {
        #[cfg(target_arch = "x86_64")]
        if backend == SimdBackend::Avx2 {
            return microkernel_f64_avx2(kc, ap, bp, c, ldc, mr, nr, store, alpha);
        }
        #[cfg(target_arch = "aarch64")]
        if backend == SimdBackend::Neon {
            return microkernel_f64_neon(kc, ap, bp, c, ldc, mr, nr, store, alpha);
        }
        let _ = backend;
        microkernel_f64_scalar(kc, ap, bp, c, ldc, mr, nr, store, alpha)
    }
}

impl Element for f32 {
    const MR: usize = MR_F32;
    const NR: usize = NR_F32;
    const ZERO: f32 = 0.0;

    #[inline(always)]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }

    /// # Safety
    /// See [`Element::microkernel`].
    #[inline(always)]
    unsafe fn microkernel(
        backend: SimdBackend,
        kc: usize,
        ap: *const f32,
        bp: *const f32,
        c: *mut f64,
        ldc: usize,
        mr: usize,
        nr: usize,
        store: bool,
        alpha: f64,
    ) {
        #[cfg(target_arch = "x86_64")]
        if backend == SimdBackend::Avx2 {
            return microkernel_f32_avx2(kc, ap, bp, c, ldc, mr, nr, store, alpha);
        }
        #[cfg(target_arch = "aarch64")]
        if backend == SimdBackend::Neon {
            return microkernel_f32_neon(kc, ap, bp, c, ldc, mr, nr, store, alpha);
        }
        let _ = backend;
        microkernel_f32_scalar(kc, ap, bp, c, ldc, mr, nr, store, alpha)
    }
}

/// Borrowed view of an m×k operand: element (i, j) lives at
/// `data[i*ld + j]`, or at `data[j*ld + i]` when `trans` is set (the
/// view then presents the transpose of the underlying storage).
#[derive(Clone, Copy)]
struct Panel<'a> {
    data: &'a [f64],
    /// operator rows (after any transpose)
    rows: usize,
    /// operator cols (after any transpose)
    cols: usize,
    /// row stride of the underlying storage
    ld: usize,
    trans: bool,
}

impl<'a> Panel<'a> {
    fn normal(m: &'a Mat) -> Panel<'a> {
        Panel {
            data: &m.data,
            rows: m.rows,
            cols: m.cols,
            ld: m.cols,
            trans: false,
        }
    }

    fn transposed(m: &'a Mat) -> Panel<'a> {
        Panel {
            data: &m.data,
            rows: m.cols,
            cols: m.rows,
            ld: m.cols,
            trans: true,
        }
    }

    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f64 {
        if self.trans {
            self.data[j * self.ld + i]
        } else {
            self.data[i * self.ld + j]
        }
    }
}

// ---------------------------------------------------------------------
// micro-kernels (the rungs of the dispatch ladder)

/// 4×8 f64 scalar micro-kernel over packed panels.
///
/// `ap` holds `kc` steps of MR interleaved A values, `bp` holds `kc`
/// steps of NR interleaved B values.  The full MR×NR accumulator is
/// always computed (panels are zero-padded); only the `mr`×`nr` valid
/// corner is written back.
///
/// # Safety
/// See [`Element::microkernel`].
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn microkernel_f64_scalar(
    kc: usize,
    ap: *const f64,
    bp: *const f64,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    let mut acc = [[0.0f64; NR_F64]; MR_F64];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F64);
        let bpk = bp.add(kk * NR_F64);
        let a0 = *apk;
        let a1 = *apk.add(1);
        let a2 = *apk.add(2);
        let a3 = *apk.add(3);
        for cc in 0..NR_F64 {
            let bv = *bpk.add(cc);
            acc[0][cc] += a0 * bv;
            acc[1][cc] += a1 * bv;
            acc[2][cc] += a2 * bv;
            acc[3][cc] += a3 * bv;
        }
    }
    write_tile_f64(&acc, c, ldc, mr, nr, store, alpha);
}

/// 8×8 f32 scalar micro-kernel: the KC-block partial product reduces
/// in f32 registers and folds into the f64 C tile (cross-block
/// accumulation stays f64).
///
/// # Safety
/// See [`Element::microkernel`].
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn microkernel_f32_scalar(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    let mut acc = [[0.0f32; NR_F32]; MR_F32];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F32);
        let bpk = bp.add(kk * NR_F32);
        for (r, accr) in acc.iter_mut().enumerate() {
            let ar = *apk.add(r);
            for (cc, slot) in accr.iter_mut().enumerate() {
                *slot += ar * *bpk.add(cc);
            }
        }
    }
    write_tile_f32(&acc, c, ldc, mr, nr, store, alpha);
}

/// Write back the valid `mr`×`nr` corner of an f64 accumulator tile.
///
/// # Safety
/// `c` must be valid for the tile at stride `ldc` with exclusive access.
#[inline(always)]
unsafe fn write_tile_f64(
    acc: &[[f64; NR_F64]; MR_F64],
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    for (r, accr) in acc.iter().enumerate().take(mr) {
        let crow = c.add(r * ldc);
        for (cc, &v0) in accr.iter().enumerate().take(nr) {
            let v = alpha * v0;
            let dst = crow.add(cc);
            if store {
                *dst = v;
            } else {
                *dst += v;
            }
        }
    }
}

/// Write back the valid `mr`×`nr` corner of an f32 accumulator tile
/// into the f64 C tile (lane-wise widen, then α in f64).
///
/// # Safety
/// `c` must be valid for the tile at stride `ldc` with exclusive access.
#[inline(always)]
unsafe fn write_tile_f32(
    acc: &[[f32; NR_F32]; MR_F32],
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    for (r, accr) in acc.iter().enumerate().take(mr) {
        let crow = c.add(r * ldc);
        for (cc, &v0) in accr.iter().enumerate().take(nr) {
            let v = alpha * v0 as f64;
            let dst = crow.add(cc);
            if store {
                *dst = v;
            } else {
                *dst += v;
            }
        }
    }
}

/// AVX2 rung of the f64 ladder: 4 rows × two 4-lane ymm columns.
/// Separate mul + add (no FMA) keeps every lane's reduction chain
/// bit-identical to [`microkernel_f64_scalar`].
///
/// # Safety
/// See [`Element::microkernel`]; additionally requires AVX2 at runtime.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_f64_avx2(
    kc: usize,
    ap: *const f64,
    bp: *const f64,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    use std::arch::x86_64::*;
    let mut acc = [[_mm256_setzero_pd(); 2]; MR_F64];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F64);
        let bpk = bp.add(kk * NR_F64);
        let b0 = _mm256_loadu_pd(bpk);
        let b1 = _mm256_loadu_pd(bpk.add(4));
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_pd(*apk.add(r));
            accr[0] = _mm256_add_pd(accr[0], _mm256_mul_pd(av, b0));
            accr[1] = _mm256_add_pd(accr[1], _mm256_mul_pd(av, b1));
        }
    }
    let mut buf = [[0.0f64; NR_F64]; MR_F64];
    for (r, accr) in acc.iter().enumerate() {
        _mm256_storeu_pd(buf[r].as_mut_ptr(), accr[0]);
        _mm256_storeu_pd(buf[r].as_mut_ptr().add(4), accr[1]);
    }
    write_tile_f64(&buf, c, ldc, mr, nr, store, alpha);
}

/// AVX2 rung of the f32 ladder: 8 rows × one 8-lane ymm column.
///
/// # Safety
/// See [`Element::microkernel`]; additionally requires AVX2 at runtime.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_f32_avx2(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR_F32];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F32);
        let bv = _mm256_loadu_ps(bp.add(kk * NR_F32));
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*apk.add(r));
            *accr = _mm256_add_ps(*accr, _mm256_mul_ps(av, bv));
        }
    }
    let mut buf = [[0.0f32; NR_F32]; MR_F32];
    for (r, accr) in acc.iter().enumerate() {
        _mm256_storeu_ps(buf[r].as_mut_ptr(), *accr);
    }
    write_tile_f32(&buf, c, ldc, mr, nr, store, alpha);
}

/// NEON rung of the f64 ladder: 4 rows × four 2-lane q-register
/// columns.  Explicit mul + add (not `vfmaq`) for scalar bit-identity.
///
/// # Safety
/// See [`Element::microkernel`].
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn microkernel_f64_neon(
    kc: usize,
    ap: *const f64,
    bp: *const f64,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    use std::arch::aarch64::*;
    let mut acc = [[vdupq_n_f64(0.0); 4]; MR_F64];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F64);
        let bpk = bp.add(kk * NR_F64);
        let b = [
            vld1q_f64(bpk),
            vld1q_f64(bpk.add(2)),
            vld1q_f64(bpk.add(4)),
            vld1q_f64(bpk.add(6)),
        ];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f64(*apk.add(r));
            for (q, bq) in b.iter().enumerate() {
                accr[q] = vaddq_f64(accr[q], vmulq_f64(av, *bq));
            }
        }
    }
    let mut buf = [[0.0f64; NR_F64]; MR_F64];
    for (r, accr) in acc.iter().enumerate() {
        for (q, aq) in accr.iter().enumerate() {
            vst1q_f64(buf[r].as_mut_ptr().add(2 * q), *aq);
        }
    }
    write_tile_f64(&buf, c, ldc, mr, nr, store, alpha);
}

/// NEON rung of the f32 ladder: 8 rows × two 4-lane q-register columns.
///
/// # Safety
/// See [`Element::microkernel`].
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn microkernel_f32_neon(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f64,
    ldc: usize,
    mr: usize,
    nr: usize,
    store: bool,
    alpha: f64,
) {
    use std::arch::aarch64::*;
    let mut acc = [[vdupq_n_f32(0.0); 2]; MR_F32];
    for kk in 0..kc {
        let apk = ap.add(kk * MR_F32);
        let bpk = bp.add(kk * NR_F32);
        let b0 = vld1q_f32(bpk);
        let b1 = vld1q_f32(bpk.add(4));
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f32(*apk.add(r));
            accr[0] = vaddq_f32(accr[0], vmulq_f32(av, b0));
            accr[1] = vaddq_f32(accr[1], vmulq_f32(av, b1));
        }
    }
    let mut buf = [[0.0f32; NR_F32]; MR_F32];
    for (r, accr) in acc.iter().enumerate() {
        vst1q_f32(buf[r].as_mut_ptr(), accr[0]);
        vst1q_f32(buf[r].as_mut_ptr().add(4), accr[1]);
    }
    write_tile_f32(&buf, c, ldc, mr, nr, store, alpha);
}

// ---------------------------------------------------------------------
// blocked driver

/// Blocked packed GEMM: C ⟵ α·A·B (`accumulate = false`) or
/// C += α·A·B (`accumulate = true`), with C row-major at stride `ldc`.
/// Generic over the pack/kernel [`Element`]; C is always f64.
///
/// # Safety
/// `c` must be valid for `(m-1)*ldc + n` elements with exclusive
/// access for the duration of the call.
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_driver<T: Element>(
    a: Panel,
    b: Panel,
    c: *mut f64,
    ldc: usize,
    accumulate: bool,
    alpha: f64,
    threads: usize,
    backend: SimdBackend,
) {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    debug_assert_eq!(b.rows, k, "gemm driver inner-dim mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            for i in 0..m {
                std::slice::from_raw_parts_mut(c.add(i * ldc), n).fill(0.0);
            }
        }
        return;
    }

    let cshared = AtomicPtr::new(c);
    // one B-pack buffer reused across every (jc, pc) pass — the pack
    // loops overwrite every slot they use (padding written explicitly)
    let mut bpack = vec![T::ZERO; (NC.min(n).div_ceil(T::NR) * T::NR) * KC.min(k)];
    for jc0 in (0..n).step_by(NC) {
        let nc_eff = NC.min(n - jc0);
        let ncr = nc_eff.div_ceil(T::NR) * T::NR;
        for pc0 in (0..k).step_by(KC) {
            let kc_eff = KC.min(k - pc0);
            let store = pc0 == 0 && !accumulate;
            pack_b_panel::<T>(b, jc0, nc_eff, pc0, kc_eff, &mut bpack[..ncr * kc_eff]);
            gemm_pass::<T>(
                a,
                &bpack[..ncr * kc_eff],
                &cshared,
                ldc,
                jc0,
                nc_eff,
                pc0,
                kc_eff,
                store,
                alpha,
                threads,
                backend,
            );
        }
    }
}

/// Pack one KC×NC panel of B into `dst` as ncr/NR sub-panels of NR
/// interleaved columns — exactly the layout the micro-kernel consumes.
/// Shared by the per-call driver and [`PrepackedB`] (whose panels must
/// be byte-identical to the on-the-fly pack for the bit-identity
/// guarantee).
fn pack_b_panel<T: Element>(
    b: Panel,
    jc0: usize,
    nc_eff: usize,
    pc0: usize,
    kc_eff: usize,
    dst: &mut [T],
) {
    let ncr = nc_eff.div_ceil(T::NR) * T::NR;
    debug_assert_eq!(dst.len(), ncr * kc_eff, "B panel buffer size");
    for q in 0..ncr / T::NR {
        let joff = jc0 + q * T::NR;
        let dst0 = q * T::NR * kc_eff;
        for kk in 0..kc_eff {
            let d = dst0 + kk * T::NR;
            for cc in 0..T::NR {
                let j = joff + cc;
                dst[d + cc] = if j < jc0 + nc_eff {
                    T::from_f64(b.at(pc0 + kk, j))
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// One (jc, pc) pass of the blocked driver against an already-packed B
/// panel: pack MC-row A blocks and sweep the micro-tiles, with the row
/// blocks fanned over the pool.  Shared by [`gemm_driver`] (per-call
/// pack) and [`gemm_driver_prepacked`] (panels packed once at load
/// time), so the two paths run literally the same tile sweep and are
/// bit-for-bit identical.
///
/// # Safety
/// `cshared` must point to a C buffer valid for `(m-1)*ldc + jc0 +
/// nc_eff` elements with exclusive access; `bpack_ref` must hold the
/// `ncr * kc_eff` panel for this (jc, pc) pass.
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_pass<T: Element>(
    a: Panel,
    bpack_ref: &[T],
    cshared: &AtomicPtr<f64>,
    ldc: usize,
    jc0: usize,
    nc_eff: usize,
    pc0: usize,
    kc_eff: usize,
    store: bool,
    alpha: f64,
    threads: usize,
    backend: SimdBackend,
) {
    let m = a.rows;
    let nblocks = m.div_ceil(MC);
    let ncr = nc_eff.div_ceil(T::NR) * T::NR;
    parallel_ranges(nblocks, threads, |range| {
        let cbase = cshared.load(Ordering::Relaxed);
        let mut apack = vec![T::ZERO; MC * kc_eff];
        for blk in range {
            let ic0 = blk * MC;
            let mc_eff = MC.min(m - ic0);
            let mcr = mc_eff.div_ceil(T::MR) * T::MR;

            // check-aliasing: this task owns C rows [ic0, ic0+mc_eff)
            // of the jc0..jc0+nc_eff column window
            crate::util::aliasing::claim_strided(
                cbase.wrapping_add(ic0 * ldc + jc0) as *const f64,
                mc_eff,
                nc_eff,
                ldc,
            );

            // ---- pack A block: mcr/MR panels of MR rows
            for p in 0..mcr / T::MR {
                let ioff = ic0 + p * T::MR;
                let dst0 = p * T::MR * kc_eff;
                for kk in 0..kc_eff {
                    let dst = dst0 + kk * T::MR;
                    for r in 0..T::MR {
                        let i = ioff + r;
                        apack[dst + r] = if i < ic0 + mc_eff {
                            T::from_f64(a.at(i, pc0 + kk))
                        } else {
                            T::ZERO
                        };
                    }
                }
            }

            // ---- micro-tile sweep
            for q in 0..ncr / T::NR {
                let j0 = q * T::NR;
                let nr_eff = T::NR.min(nc_eff - j0);
                for p in 0..mcr / T::MR {
                    let i0 = p * T::MR;
                    let mr_eff = T::MR.min(mc_eff - i0);
                    // SAFETY: pack offsets are in range by
                    // construction; C tiles of distinct blocks
                    // are disjoint row ranges.
                    unsafe {
                        let ap = apack.as_ptr().add(p * T::MR * kc_eff);
                        let bp = bpack_ref.as_ptr().add(q * T::NR * kc_eff);
                        let ctile = cbase.add((ic0 + i0) * ldc + jc0 + j0);
                        T::microkernel(
                            backend,
                            kc_eff,
                            ap,
                            bp,
                            ctile,
                            ldc,
                            mr_eff,
                            nr_eff,
                            store,
                            alpha,
                        );
                    }
                }
            }
        }
    });
}

/// Invoke the packed driver at the requested precision.
///
/// # Safety
/// Same contract as [`gemm_driver`].
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_driver_prec(
    prec: Precision,
    a: Panel,
    b: Panel,
    c: *mut f64,
    ldc: usize,
    accumulate: bool,
    alpha: f64,
    threads: usize,
    backend: SimdBackend,
) {
    match prec {
        Precision::F64 => {
            gemm_driver::<f64>(a, b, c, ldc, accumulate, alpha, threads, backend)
        }
        Precision::F32 => {
            gemm_driver::<f32>(a, b, c, ldc, accumulate, alpha, threads, backend)
        }
    }
}

// ---------------------------------------------------------------------
// prepacked static operands (the serving path)

/// All (jc, pc) panel buffers of one k×n operand, packed once through
/// [`pack_b_panel`] — byte-identical to what the per-call driver packs,
/// stored in the same (jc outer, pc inner) traversal order.
struct PrepackedPanels<T> {
    /// operator rows (the GEMM inner dimension k)
    k: usize,
    /// operator cols
    n: usize,
    data: Vec<T>,
    /// start offset of each (jc, pc) panel in `data`
    offsets: Vec<usize>,
}

impl<T: Element> PrepackedPanels<T> {
    fn build(b: Panel) -> PrepackedPanels<T> {
        let (k, n) = (b.rows, b.cols);
        let mut offsets = Vec::new();
        let mut total = 0usize;
        for jc0 in (0..n).step_by(NC) {
            let ncr = NC.min(n - jc0).div_ceil(T::NR) * T::NR;
            for pc0 in (0..k).step_by(KC) {
                offsets.push(total);
                total += ncr * KC.min(k - pc0);
            }
        }
        let mut data = vec![T::ZERO; total];
        let mut idx = 0;
        for jc0 in (0..n).step_by(NC) {
            let nc_eff = NC.min(n - jc0);
            let ncr = nc_eff.div_ceil(T::NR) * T::NR;
            for pc0 in (0..k).step_by(KC) {
                let kc_eff = KC.min(k - pc0);
                let off = offsets[idx];
                idx += 1;
                pack_b_panel::<T>(
                    b,
                    jc0,
                    nc_eff,
                    pc0,
                    kc_eff,
                    &mut data[off..off + ncr * kc_eff],
                );
            }
        }
        PrepackedPanels {
            k,
            n,
            data,
            offsets,
        }
    }
}

enum PrepackedData {
    F64(PrepackedPanels<f64>),
    F32(PrepackedPanels<f32>),
}

/// A static GEMM operand packed **once** into NR-column panels — the
/// serving path's weight representation.  The model forward re-packs
/// every weight matrix on every projection call even though the
/// weights never change; packing them once at load time removes that
/// per-call pack bandwidth entirely.
///
/// Two guarantees the serving engine builds on:
///
/// * **Bit-identity with the pack-per-call driver.**  Panels are
///   produced by the same [`pack_b_panel`] the driver calls, and
///   [`matmul_prepacked`] runs the same [`gemm_pass`] tile sweep, so a
///   prepacked product equals the on-the-fly packed product bit for
///   bit — across dispatch rungs, thread counts, and both precisions.
/// * **Row independence.**  The prepacked entries always take the
///   blocked driver (there is no per-call B-pack for a small-product
///   fallback to save), and each C row's reduction order is fixed by
///   the KC grid alone — so row i of the output depends only on row i
///   of A.  The micro-batching server relies on this: a request's
///   logits are bit-identical no matter which batch it rides in.
///
/// The orientation is baked in at pack time: [`PrepackedB::pack`]
/// packs B for C = A·B, [`PrepackedB::pack_nt`] packs the transpose
/// view for C = A·Bᵀ (the projection-GEMM orientation) without
/// materializing it.
pub struct PrepackedB {
    data: PrepackedData,
}

impl PrepackedB {
    /// Pack B (k×n storage) as the operand of C = A·B.
    pub fn pack(b: &Mat, prec: Precision) -> PrepackedB {
        Self::from_panel(Panel::normal(b), prec)
    }

    /// Pack B (n×k storage) as the transposed operand of C = A·Bᵀ —
    /// the layout of every projection weight in the model forward.
    pub fn pack_nt(b: &Mat, prec: Precision) -> PrepackedB {
        Self::from_panel(Panel::transposed(b), prec)
    }

    fn from_panel(p: Panel, prec: Precision) -> PrepackedB {
        let data = match prec {
            Precision::F64 => PrepackedData::F64(PrepackedPanels::build(p)),
            Precision::F32 => PrepackedData::F32(PrepackedPanels::build(p)),
        };
        PrepackedB { data }
    }

    /// Operator rows after any transpose (the GEMM inner dimension).
    pub fn op_rows(&self) -> usize {
        match &self.data {
            PrepackedData::F64(p) => p.k,
            PrepackedData::F32(p) => p.k,
        }
    }

    /// Operator cols after any transpose (the output width).
    pub fn op_cols(&self) -> usize {
        match &self.data {
            PrepackedData::F64(p) => p.n,
            PrepackedData::F32(p) => p.n,
        }
    }

    pub fn precision(&self) -> Precision {
        match &self.data {
            PrepackedData::F64(_) => Precision::F64,
            PrepackedData::F32(_) => Precision::F32,
        }
    }

    /// Bytes held by the packed panels (telemetry; f32 mode halves it).
    pub fn bytes(&self) -> usize {
        match &self.data {
            PrepackedData::F64(p) => p.data.len() * std::mem::size_of::<f64>(),
            PrepackedData::F32(p) => p.data.len() * std::mem::size_of::<f32>(),
        }
    }
}

/// Blocked GEMM against prepacked panels: identical to [`gemm_driver`]
/// with the per-pass B-pack replaced by an offset lookup.
///
/// # Safety
/// Same contract as [`gemm_driver`].
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_driver_prepacked<T: Element>(
    a: Panel,
    pb: &PrepackedPanels<T>,
    c: *mut f64,
    ldc: usize,
    accumulate: bool,
    alpha: f64,
    threads: usize,
    backend: SimdBackend,
) {
    let (m, k) = (a.rows, a.cols);
    let n = pb.n;
    debug_assert_eq!(pb.k, k, "prepacked gemm inner-dim mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            for i in 0..m {
                std::slice::from_raw_parts_mut(c.add(i * ldc), n).fill(0.0);
            }
        }
        return;
    }
    let cshared = AtomicPtr::new(c);
    let mut panel_idx = 0;
    for jc0 in (0..n).step_by(NC) {
        let nc_eff = NC.min(n - jc0);
        let ncr = nc_eff.div_ceil(T::NR) * T::NR;
        for pc0 in (0..k).step_by(KC) {
            let kc_eff = KC.min(k - pc0);
            let store = pc0 == 0 && !accumulate;
            let off = pb.offsets[panel_idx];
            panel_idx += 1;
            gemm_pass::<T>(
                a,
                &pb.data[off..off + ncr * kc_eff],
                &cshared,
                ldc,
                jc0,
                nc_eff,
                pc0,
                kc_eff,
                store,
                alpha,
                threads,
                backend,
            );
        }
    }
}

/// C = A · B (or A · Bᵀ — the orientation was baked in at pack time)
/// against a [`PrepackedB`], skipping the per-call B-pack.
pub fn matmul_prepacked(a: &Mat, pb: &PrepackedB) -> Mat {
    matmul_prepacked_with(
        a,
        pb,
        threads_for(a.rows * pb.op_cols() * a.cols),
        simd_backend(),
    )
}

/// [`matmul_prepacked`] with an explicit thread count and kernel
/// backend — exposed for the bit-identity tests and the benches.
pub fn matmul_prepacked_with(
    a: &Mat,
    pb: &PrepackedB,
    threads: usize,
    backend: SimdBackend,
) -> Mat {
    assert_eq!(a.cols, pb.op_rows(), "prepacked gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, pb.op_cols());
    let ldc = c.cols;
    // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
    unsafe {
        match &pb.data {
            PrepackedData::F64(p) => gemm_driver_prepacked::<f64>(
                Panel::normal(a),
                p,
                c.data.as_mut_ptr(),
                ldc,
                false,
                1.0,
                threads,
                backend,
            ),
            PrepackedData::F32(p) => gemm_driver_prepacked::<f32>(
                Panel::normal(a),
                p,
                c.data.as_mut_ptr(),
                ldc,
                false,
                1.0,
                threads,
                backend,
            ),
        }
    }
    debug_check_overflow(&c);
    c
}

// ---------------------------------------------------------------------
// coded static operands (serve straight from quantized codes)

/// Sub-panel column width of the coded code plane.  Both element
/// types use NR = 8, so one bit-packed code layout serves f64 and f32
/// decode alike; the assertions pin that equality so a future NR
/// change cannot silently shear the coded layout off the pack layout.
const CODED_NR: usize = 8;
const _: () = assert!(NR_F64 == CODED_NR, "coded layout assumes f64 NR == 8");
const _: () = assert!(NR_F32 == CODED_NR, "coded layout assumes f32 NR == 8");

/// Codes per bit-packed group: each group stores one width byte plus
/// 32 zigzagged codes at that width, so the framing overhead is a
/// fixed ¼ bit per weight while the width adapts to local magnitude.
const CODE_GROUP: usize = 32;

#[inline(always)]
fn zigzag(z: i32) -> u32 {
    ((z << 1) ^ (z >> 31)) as u32
}

#[inline(always)]
fn unzigzag(u: u32) -> i32 {
    ((u >> 1) as i32) ^ -((u & 1) as i32)
}

/// Append one group of zigzagged codes: a width byte (bits of the
/// group maximum), then the values packed LSB-first.
fn put_code_group(out: &mut Vec<u8>, vals: &[u32]) {
    let mut width = 0u32;
    for &v in vals {
        width = width.max(32 - v.leading_zeros());
    }
    out.push(width as u8);
    if width == 0 {
        return;
    }
    let mut acc = 0u64;
    let mut nbits = 0u32;
    for &v in vals {
        acc |= (v as u64) << nbits;
        nbits += width;
        while nbits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

/// Streaming reader over one sub-panel's bit-packed code stream.
struct CodeReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl CodeReader<'_> {
    /// Decode the next group into `out` (length ≤ [`CODE_GROUP`]).
    #[inline]
    fn read_group(&mut self, out: &mut [i32]) {
        let width = u32::from(self.bytes[self.pos]);
        self.pos += 1;
        if width == 0 {
            out.fill(0);
            return;
        }
        let mask = if width == 32 {
            u64::from(u32::MAX)
        } else {
            (1u64 << width) - 1
        };
        let mut acc = 0u64;
        let mut nbits = 0u32;
        for o in out.iter_mut() {
            while nbits < width {
                acc |= u64::from(self.bytes[self.pos]) << nbits;
                self.pos += 1;
                nbits += 8;
            }
            *o = unzigzag((acc & mask) as u32);
            acc >>= width;
            nbits -= width;
        }
    }
}

/// One stacked part of a coded operand: the quantized form of one
/// weight matrix W in `rows`×`cols` storage (codes row-major), with
/// the reconstruction Ŵ[i][j] = ((t[i]·z[i·cols+j])·γ[j])·α[j] — the
/// exact association order of the quantizer's eager dequant, so
/// decoding inside the pack stage and dequantizing eagerly then
/// packing produce the same f64 value bit for bit.  Under the
/// [`CodedPanel::pack_nt_parts`] orientation (operand = Ŵᵀ), storage
/// rows stack into operand *columns* — the fused-projection layout
/// ([wq; wk; wv] etc.).
#[derive(Clone, Copy)]
pub struct CodedPart<'a> {
    /// integer codes, row-major `rows`×`cols`
    pub z: &'a [i32],
    /// per-storage-row rescalers T (len `rows`)
    pub t: &'a [f64],
    /// per-storage-column rescalers γ (len `cols`)
    pub gammas: &'a [f64],
    /// per-storage-column grid spacings α (len `cols`)
    pub alphas: &'a [f64],
    pub rows: usize,
    pub cols: usize,
}

/// Owned side information of one coded part.
struct CodedPartMeta {
    /// first operand column of this part in the stacked operand
    col0: usize,
    gammas: Vec<f64>,
    alphas: Vec<f64>,
}

/// A static GEMM operand kept in *quantized* form: the integer codes
/// stay resident bit-packed in exactly the (jc, pc, q) sub-panel
/// traversal order of [`pack_b_panel`], and each (jc, pc) panel is
/// dequantized on the fly into an L2/L3-resident scratch that feeds
/// the unchanged [`gemm_pass`] tile sweep.  Resident weight bytes drop
/// to roughly the artifact size while every dispatch rung and both
/// precisions inherit the path for free.
///
/// Bit-identity: the decode computes `from_f64(((t·z)·γ)·α)` — the
/// same f64 expression, in the same association order, at the same
/// panel position as eagerly dequantizing the codes and packing
/// through [`pack_b_panel`] — and then runs the same tile sweep, so
/// [`matmul_coded`] equals [`matmul_prepacked`] over the
/// eagerly-dequantized weights bit for bit, across dispatch rungs,
/// thread counts, and f32/f64.
pub struct CodedPanel {
    /// operand rows (the GEMM inner dimension k = storage cols)
    k: usize,
    /// operand cols (sum of part storage rows)
    n: usize,
    prec: Precision,
    parts: Vec<CodedPartMeta>,
    /// per operand column: the part's row rescaler t
    col_t: Vec<f64>,
    /// per operand column: owning part index
    col_part: Vec<u32>,
    /// bit-packed zigzag codes, one independent stream per (jc, pc, q)
    /// sub-panel so panel decode can fan sub-panels over the pool
    codes: Vec<u8>,
    /// byte offset of each sub-panel stream in `codes` + end sentinel
    sub_offsets: Vec<usize>,
}

impl CodedPanel {
    /// Pack the quantized parts as the transposed operand of C = A·Ŵᵀ
    /// (the projection orientation; parts stack top-to-bottom exactly
    /// like the eager fused operand).  Errors on inconsistent part
    /// shapes — corrupted code planes must never build a panel that
    /// could index out of bounds later.
    pub fn pack_nt_parts(parts: &[CodedPart], prec: Precision) -> Result<CodedPanel, String> {
        if parts.is_empty() {
            return Err("coded operand needs at least one part".to_string());
        }
        let k = parts[0].cols;
        let mut n = 0usize;
        for (idx, p) in parts.iter().enumerate() {
            if p.cols != k {
                return Err(format!(
                    "coded part {idx}: {} storage cols != shared {k}",
                    p.cols
                ));
            }
            let codes = p.rows.checked_mul(p.cols).ok_or_else(|| {
                format!("coded part {idx}: {}x{} overflows", p.rows, p.cols)
            })?;
            if p.z.len() != codes {
                return Err(format!(
                    "coded part {idx}: {} codes for {}x{} storage",
                    p.z.len(),
                    p.rows,
                    p.cols
                ));
            }
            if p.t.len() != p.rows {
                return Err(format!(
                    "coded part {idx}: {} row rescalers for {} rows",
                    p.t.len(),
                    p.rows
                ));
            }
            if p.gammas.len() != k || p.alphas.len() != k {
                return Err(format!(
                    "coded part {idx}: {}γ/{}α for {k} storage cols",
                    p.gammas.len(),
                    p.alphas.len()
                ));
            }
            n += p.rows;
        }

        let mut metas = Vec::with_capacity(parts.len());
        let mut col_t = Vec::with_capacity(n);
        let mut col_part = Vec::with_capacity(n);
        let mut col0 = 0usize;
        for (idx, p) in parts.iter().enumerate() {
            metas.push(CodedPartMeta {
                col0,
                gammas: p.gammas.to_vec(),
                alphas: p.alphas.to_vec(),
            });
            col_t.extend_from_slice(p.t);
            col_part.extend(std::iter::repeat_n(idx as u32, p.rows));
            col0 += p.rows;
        }

        // encode the code plane in pack traversal order: operand column
        // j ↔ storage row of its part, operand row kk ↔ storage column
        let mut codes = Vec::new();
        let mut sub_offsets = Vec::new();
        let mut grp = [0u32; CODE_GROUP];
        for jc0 in (0..n).step_by(NC) {
            let nc_eff = NC.min(n - jc0);
            let ncr = nc_eff.div_ceil(CODED_NR) * CODED_NR;
            for pc0 in (0..k).step_by(KC) {
                let kc_eff = KC.min(k - pc0);
                for q in 0..ncr / CODED_NR {
                    let joff = jc0 + q * CODED_NR;
                    let valid = CODED_NR.min(jc0 + nc_eff - joff);
                    sub_offsets.push(codes.len());
                    let mut gi = 0usize;
                    for kk in 0..kc_eff {
                        for cc in 0..valid {
                            let j = joff + cc;
                            let p = col_part[j] as usize;
                            let local = j - metas[p].col0;
                            grp[gi] = zigzag(parts[p].z[local * k + pc0 + kk]);
                            gi += 1;
                            if gi == CODE_GROUP {
                                put_code_group(&mut codes, &grp);
                                gi = 0;
                            }
                        }
                    }
                    if gi > 0 {
                        put_code_group(&mut codes, &grp[..gi]);
                    }
                }
            }
        }
        sub_offsets.push(codes.len());
        codes.shrink_to_fit();

        Ok(CodedPanel {
            k,
            n,
            prec,
            parts: metas,
            col_t,
            col_part,
            codes,
            sub_offsets,
        })
    }

    /// Operand rows after the transpose (the GEMM inner dimension).
    pub fn op_rows(&self) -> usize {
        self.k
    }

    /// Operand cols (the output width).
    pub fn op_cols(&self) -> usize {
        self.n
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Resident bytes of the coded operand: the bit-packed code plane
    /// plus every piece of side information held for decode (f64 row/
    /// column rescalers, part map, sub-panel offsets).  This — not the
    /// code plane alone — is what the serving telemetry compares to
    /// the artifact size.
    pub fn bytes(&self) -> usize {
        self.codes.len()
            + self.sub_offsets.len() * std::mem::size_of::<usize>()
            + self.col_t.len() * std::mem::size_of::<f64>()
            + self.col_part.len() * std::mem::size_of::<u32>()
            + self
                .parts
                .iter()
                .map(|p| (p.gammas.len() + p.alphas.len()) * std::mem::size_of::<f64>())
                .sum::<usize>()
    }

    /// Decode one (jc, pc) panel into `dst` in the exact
    /// [`pack_b_panel`] layout, fanning the independent q sub-panels
    /// over the pool: at decode widths the tile sweep is a single
    /// MC block (serial), so the decode itself must parallelize for
    /// the coded path to beat streaming eager panels from DRAM.
    #[allow(clippy::too_many_arguments)]
    fn decode_panel<T: Element>(
        &self,
        sub0: usize,
        jc0: usize,
        nc_eff: usize,
        pc0: usize,
        kc_eff: usize,
        dst: &mut [T],
        threads: usize,
    ) {
        debug_assert_eq!(T::NR, CODED_NR, "coded layout pins NR == 8");
        let nq = nc_eff.div_ceil(CODED_NR);
        debug_assert_eq!(dst.len(), nq * CODED_NR * kc_eff, "coded panel buffer size");
        let dshared = AtomicPtr::new(dst.as_mut_ptr());
        parallel_ranges(nq, threads, |range| {
            let base = dshared.load(Ordering::Relaxed);
            for q in range {
                let off = q * CODED_NR * kc_eff;
                // check-aliasing: this task owns sub-panel q's slice
                crate::util::aliasing::claim(
                    base.wrapping_add(off) as *const T,
                    CODED_NR * kc_eff,
                );
                let joff = jc0 + q * CODED_NR;
                let valid = CODED_NR.min(jc0 + nc_eff - joff);
                // SAFETY: sub-panels occupy disjoint `CODED_NR * kc_eff`
                // slices of `dst`, each claimed by exactly one task.
                let sub = unsafe {
                    std::slice::from_raw_parts_mut(base.add(off), CODED_NR * kc_eff)
                };
                self.decode_sub::<T>(sub0 + q, joff, valid, pc0, kc_eff, sub);
            }
        });
    }

    /// Decode one q sub-panel (NR interleaved operand columns) into
    /// `dst`, padding columns past `valid` with zero exactly like
    /// [`pack_b_panel`].
    fn decode_sub<T: Element>(
        &self,
        sub: usize,
        joff: usize,
        valid: usize,
        pc0: usize,
        kc_eff: usize,
        dst: &mut [T],
    ) {
        let mut rd = CodeReader {
            bytes: &self.codes[self.sub_offsets[sub]..self.sub_offsets[sub + 1]],
            pos: 0,
        };
        let mut tcol = [0.0f64; CODED_NR];
        for cc in 0..valid {
            tcol[cc] = self.col_t[joff + cc];
        }
        let mut grp = [0i32; CODE_GROUP];
        let mut remaining = valid * kc_eff;
        let mut gi = 0usize;
        let mut gn = 0usize;
        // hot path: every column of the sub-panel in one part (part
        // boundaries are storage-row counts, usually multiples of NR),
        // so γ/α are scalars per kk
        let one_part = valid > 0
            && (1..valid).all(|cc| {
                self.col_part[joff + cc] == self.col_part[joff]
            });
        if one_part {
            let meta = &self.parts[self.col_part[joff] as usize];
            for kk in 0..kc_eff {
                let g = meta.gammas[pc0 + kk];
                let al = meta.alphas[pc0 + kk];
                let d = kk * CODED_NR;
                for cc in 0..valid {
                    if gi == gn {
                        gn = remaining.min(CODE_GROUP);
                        rd.read_group(&mut grp[..gn]);
                        remaining -= gn;
                        gi = 0;
                    }
                    let zf = f64::from(grp[gi]);
                    gi += 1;
                    dst[d + cc] = T::from_f64(((tcol[cc] * zf) * g) * al);
                }
                for cc in valid..CODED_NR {
                    dst[d + cc] = T::ZERO;
                }
            }
        } else {
            for kk in 0..kc_eff {
                let d = kk * CODED_NR;
                for cc in 0..valid {
                    if gi == gn {
                        gn = remaining.min(CODE_GROUP);
                        rd.read_group(&mut grp[..gn]);
                        remaining -= gn;
                        gi = 0;
                    }
                    let zf = f64::from(grp[gi]);
                    gi += 1;
                    let meta = &self.parts[self.col_part[joff + cc] as usize];
                    dst[d + cc] = T::from_f64(
                        ((tcol[cc] * zf) * meta.gammas[pc0 + kk]) * meta.alphas[pc0 + kk],
                    );
                }
                for cc in valid..CODED_NR {
                    dst[d + cc] = T::ZERO;
                }
            }
        }
        debug_assert_eq!(remaining, 0, "coded sub-panel code count");
        debug_assert_eq!(
            rd.pos,
            self.sub_offsets[sub + 1] - self.sub_offsets[sub],
            "coded sub-panel stream length"
        );
    }
}

/// Blocked GEMM against a coded operand: identical to
/// [`gemm_driver_prepacked`] with the offset lookup replaced by a
/// per-(jc, pc) panel decode into a reused scratch buffer.
///
/// # Safety
/// Same contract as [`gemm_driver`].
unsafe fn gemm_driver_coded<T: Element>(
    a: Panel,
    cp: &CodedPanel,
    c: *mut f64,
    ldc: usize,
    threads: usize,
    backend: SimdBackend,
) {
    let (m, k) = (a.rows, a.cols);
    let n = cp.n;
    debug_assert_eq!(cp.k, k, "coded gemm inner-dim mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        for i in 0..m {
            std::slice::from_raw_parts_mut(c.add(i * ldc), n).fill(0.0);
        }
        return;
    }
    let cshared = AtomicPtr::new(c);
    // one decode scratch reused across every (jc, pc) panel — the
    // decode loops overwrite every slot they use (padding explicit)
    let mut scratch =
        vec![T::ZERO; (NC.min(n).div_ceil(CODED_NR) * CODED_NR) * KC.min(k)];
    let mut sub_idx = 0usize;
    for jc0 in (0..n).step_by(NC) {
        let nc_eff = NC.min(n - jc0);
        let ncr = nc_eff.div_ceil(CODED_NR) * CODED_NR;
        for pc0 in (0..k).step_by(KC) {
            let kc_eff = KC.min(k - pc0);
            let store = pc0 == 0;
            cp.decode_panel::<T>(
                sub_idx,
                jc0,
                nc_eff,
                pc0,
                kc_eff,
                &mut scratch[..ncr * kc_eff],
                threads,
            );
            sub_idx += ncr / CODED_NR;
            gemm_pass::<T>(
                a,
                &scratch[..ncr * kc_eff],
                &cshared,
                ldc,
                jc0,
                nc_eff,
                pc0,
                kc_eff,
                store,
                1.0,
                threads,
                backend,
            );
        }
    }
}

/// C = A · Ŵᵀ against a [`CodedPanel`], decoding the quantized codes
/// per KC block inside the pack stage — bit-identical to
/// [`matmul_prepacked`] over the eagerly-dequantized weights.
pub fn matmul_coded(a: &Mat, cp: &CodedPanel) -> Mat {
    // decode work is k·n regardless of m, so the fan-out policy sees
    // at least a decode-batch-sized m — a 1-row decode step must still
    // parallelize the panel decode
    matmul_coded_with(
        a,
        cp,
        threads_for(a.rows.max(8) * cp.op_cols() * a.cols),
        simd_backend(),
    )
}

/// [`matmul_coded`] with an explicit thread count and kernel backend —
/// exposed for the bit-identity tests and the benches.
pub fn matmul_coded_with(
    a: &Mat,
    cp: &CodedPanel,
    threads: usize,
    backend: SimdBackend,
) -> Mat {
    assert_eq!(a.cols, cp.op_rows(), "coded gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, cp.op_cols());
    let ldc = c.cols.max(1);
    // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
    unsafe {
        match cp.precision() {
            Precision::F64 => gemm_driver_coded::<f64>(
                Panel::normal(a),
                cp,
                c.data.as_mut_ptr(),
                ldc,
                threads,
                backend,
            ),
            Precision::F32 => gemm_driver_coded::<f32>(
                Panel::normal(a),
                cp,
                c.data.as_mut_ptr(),
                ldc,
                threads,
                backend,
            ),
        }
    }
    debug_check_overflow(&c);
    c
}

/// Work-size parallelism policy shared by every dense kernel layer
/// (gemm wrappers here, the blocked Cholesky/TRSM in `chol`): fan out
/// only past the point where pool handoff costs less than the flops.
pub(crate) fn threads_for(work: usize) -> usize {
    if work > 1 << 18 {
        default_threads()
    } else {
        1
    }
}

/// Serial fallback for small products (ikj order, C row hot).
fn matmul_small_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let n = b.cols;
    let k = a.cols;
    for i in 0..a.rows {
        let crow = c.row_mut(i);
        crow.fill(0.0);
        let arow = a.row(i);
        for kk in 0..k {
            let aik = arow[kk];
            let brow = b.row(kk);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Sampled overflow check (debug builds only): a ±∞ in C means the
/// product overflowed somewhere.  O(16) instead of the O(mn) full scan
/// the seed kernel paid on every call.
fn debug_check_overflow(c: &Mat) {
    if cfg!(debug_assertions) && !c.data.is_empty() {
        let step = (c.data.len() / 16).max(1);
        for idx in (0..c.data.len()).step_by(step) {
            debug_assert!(
                !c.data[idx].is_infinite(),
                "gemm output overflowed to ±∞ at flat index {idx}"
            );
        }
    }
}

/// C = A · B
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B at the given kernel precision (see module docs; shapes
/// below the packed threshold always compute in f64).
pub fn matmul_prec(a: &Mat, b: &Mat, prec: Precision) -> Mat {
    match prec {
        Precision::F64 => matmul(a, b),
        Precision::F32 => matmul_f32(a, b),
    }
}

/// C = A · B through the f32 packed path: pack/multiply in f32 (double
/// lanes, half pack bandwidth), per-KC-block accumulation in f64.
pub fn matmul_f32(a: &Mat, b: &Mat) -> Mat {
    matmul_f32_with(a, b, threads_for(a.rows * b.cols * a.cols), simd_backend())
}

/// [`matmul_f32`] with an explicit thread count and kernel backend —
/// exposed for dispatch-equivalence tests and the benches (forcing
/// [`SimdBackend::Scalar`] measures the ladder's fallback rung).
pub fn matmul_f32_with(a: &Mat, b: &Mat, threads: usize, backend: SimdBackend) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into_with(a, b, &mut c, threads, backend, Precision::F32);
    c
}

/// C = A · B with an explicit thread count — the threaded and
/// single-threaded results are bit-for-bit identical (see module docs);
/// exposed for determinism tests and tuning.
pub fn matmul_with_threads(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into_threads(a, b, &mut c, threads);
    c
}

/// C = A · B (C pre-allocated, overwritten).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let threads = threads_for(a.rows * b.cols * a.cols);
    matmul_into_threads(a, b, c, threads);
}

fn matmul_into_threads(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    matmul_into_with(a, b, c, threads, simd_backend(), Precision::F64);
}

/// Shared C = A·B body: shape checks, small-product fallback, packed
/// driver at the requested precision/backend, overflow sampling.
fn matmul_into_with(
    a: &Mat,
    b: &Mat,
    c: &mut Mat,
    threads: usize,
    backend: SimdBackend,
    prec: Precision,
) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    if a.rows * b.cols * a.cols <= SMALL_GEMM {
        matmul_small_into(a, b, c);
    } else {
        let ldc = c.cols;
        // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
        unsafe {
            gemm_driver_prec(
                prec,
                Panel::normal(a),
                Panel::normal(b),
                c.data.as_mut_ptr(),
                ldc,
                false,
                1.0,
                threads,
                backend,
            );
        }
    }
    debug_check_overflow(c);
}

/// C = A · Bᵀ without materializing the transpose.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    matmul_nt_prec(a, b, Precision::F64)
}

/// [`matmul_nt`] at the given kernel precision — the model forward
/// routes its projection gemms through this.
pub fn matmul_nt_prec(a: &Mat, b: &Mat, prec: Precision) -> Mat {
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch");
    let n = b.rows;
    let mut c = Mat::zeros(a.rows, n);
    if a.rows * n * a.cols <= SMALL_GEMM {
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] = super::dot(arow, b.row(j));
            }
        }
    } else {
        let threads = threads_for(a.rows * n * a.cols);
        // SAFETY: c.data is exactly rows×cols and exclusively borrowed.
        unsafe {
            gemm_driver_prec(
                prec,
                Panel::normal(a),
                Panel::transposed(b),
                c.data.as_mut_ptr(),
                n,
                false,
                1.0,
                threads,
                simd_backend(),
            );
        }
    }
    debug_check_overflow(&c);
    c
}

/// C += Xᵀ · Y (cross-moment accumulation; X is r×m, Y is r×n, C is
/// m×n).  The covariance accumulators stream panels through this.
pub fn matmul_tn_acc(x: &Mat, y: &Mat, c: &mut Mat) {
    matmul_tn_acc_prec(x, y, c, Precision::F64)
}

/// [`matmul_tn_acc`] at the given kernel precision: panels pack and
/// multiply in f32, the running moment C stays f64.
pub fn matmul_tn_acc_prec(x: &Mat, y: &Mat, c: &mut Mat, prec: Precision) {
    assert_eq!(x.rows, y.rows, "gemm_tn shape mismatch");
    assert_eq!((c.rows, c.cols), (x.cols, y.cols));
    let (m, k, n) = (x.cols, x.rows, y.cols);
    if m * k * n <= SMALL_GEMM {
        for r in 0..k {
            let xr = x.row(r);
            let yr = y.row(r);
            for i in 0..m {
                let xi = xr[i];
                if xi == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for j in 0..n {
                    crow[j] += xi * yr[j];
                }
            }
        }
        return;
    }
    let threads = threads_for(m * k * n);
    // SAFETY: c.data is exactly m×n and exclusively borrowed.
    unsafe {
        gemm_driver_prec(
            prec,
            Panel::transposed(x),
            Panel::normal(y),
            c.data.as_mut_ptr(),
            n,
            true,
            1.0,
            threads,
            simd_backend(),
        );
    }
}

/// C = Aᵀ · A (Gram matrix), exploiting symmetry: only upper-triangle
/// blocks are computed (in parallel), the strict lower triangle is
/// mirrored.  The covariance accumulator reduces to this on activation
/// panels.
pub fn gram(a: &Mat) -> Mat {
    gram_with_threads(a, threads_for(a.rows * a.cols * a.cols))
}

/// [`gram`] at the given kernel precision.
pub fn gram_prec(a: &Mat, prec: Precision) -> Mat {
    gram_threads_prec(a, threads_for(a.rows * a.cols * a.cols), prec)
}

/// [`gram`] with an explicit thread count (bit-for-bit identical across
/// thread counts; exposed for determinism tests and tuning).
pub fn gram_with_threads(a: &Mat, threads: usize) -> Mat {
    gram_threads_prec(a, threads, Precision::F64)
}

fn gram_threads_prec(a: &Mat, threads: usize, prec: Precision) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    syrk_upper(a, &mut c, threads, prec);
    mirror_lower(&mut c);
    c
}

/// C += Aᵀ · A for a symmetric accumulator.  C must be exactly
/// symmetric on entry (e.g. zero, or only ever updated through this
/// function): the update computes upper-triangle blocks and mirrors,
/// which preserves exact symmetry.
pub fn gram_acc(a: &Mat, c: &mut Mat) {
    gram_acc_prec(a, c, Precision::F64)
}

/// [`gram_acc`] at the given kernel precision (C stays f64).
pub fn gram_acc_prec(a: &Mat, c: &mut Mat, prec: Precision) {
    assert_eq!((c.rows, c.cols), (a.cols, a.cols), "gram_acc shape");
    syrk_upper(a, c, threads_for(a.rows * a.cols * a.cols), prec);
    mirror_lower(c);
}

/// Accumulate the upper triangle (incl. diagonal blocks in full) of
/// Aᵀ·A into C.
fn syrk_upper(a: &Mat, c: &mut Mat, threads: usize, prec: Precision) {
    let n = a.cols;
    let m = a.rows;
    if n == 0 || m == 0 {
        return;
    }
    if m * n * n <= SMALL_GEMM {
        // serial triangle, row-streaming
        for r in 0..m {
            let row = a.row(r);
            for i in 0..n {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for j in i..n {
                    crow[j] += xi * row[j];
                }
            }
        }
        return;
    }

    // output-block edge for the symmetric sweep
    const GB: usize = 64;
    let nb = n.div_ceil(GB);
    let pairs: Vec<(usize, usize)> = (0..nb)
        .flat_map(|i| (i..nb).map(move |j| (i, j)))
        .collect();
    let cptr = AtomicPtr::new(c.data.as_mut_ptr());
    let adata = &a.data;
    let backend = simd_backend();
    parallel_ranges(pairs.len(), threads, |range| {
        let base = cptr.load(Ordering::Relaxed);
        for t in range {
            let (bi, bj) = pairs[t];
            let i0 = bi * GB;
            let i1 = ((bi + 1) * GB).min(n);
            let j0 = bj * GB;
            let j1 = ((bj + 1) * GB).min(n);
            // check-aliasing: this task owns the C tile
            // [i0..i1)×[j0..j1)
            crate::util::aliasing::claim_strided(
                base.wrapping_add(i0 * n + j0) as *const f64,
                i1 - i0,
                j1 - j0,
                n,
            );
            // C[i0..i1, j0..j1] += A[:, i0..i1]ᵀ · A[:, j0..j1]
            let at = Panel {
                data: &adata[i0..],
                rows: i1 - i0,
                cols: m,
                ld: n,
                trans: true,
            };
            let ap = Panel {
                data: &adata[j0..],
                rows: m,
                cols: j1 - j0,
                ld: n,
                trans: false,
            };
            // SAFETY: block (bi, bj) owns the disjoint C region
            // [i0..i1)×[j0..j1); serial inner driver (threads = 1).
            unsafe {
                gemm_driver_prec(prec, at, ap, base.add(i0 * n + j0), n, true, 1.0, 1, backend);
            }
        }
    });
}

fn mirror_lower(c: &mut Mat) {
    for i in 1..c.rows {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

/// C += α · A·B over raw strided views (A is m×k at stride `a_ld`, B is
/// k×n at stride `b_ld`, C is m×n at stride `c_ld`).  Fused panel
/// update for the ZSIC/GPTQ deferred rank-B interference subtraction —
/// the α = −1 path replaces the per-element axpy sweep.  Always f64:
/// the quantizer core is pinned for reproducibility of the paper's
/// numbers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_acc_strided(
    m: usize,
    k: usize,
    n: usize,
    a_data: &[f64],
    a_ld: usize,
    b_data: &[f64],
    b_ld: usize,
    c_data: &mut [f64],
    c_ld: usize,
    alpha: f64,
    threads: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(a_data.len() >= (m - 1) * a_ld + k);
    debug_assert!(b_data.len() >= (k - 1) * b_ld + n);
    debug_assert!(c_data.len() >= (m - 1) * c_ld + n);
    let ap = Panel {
        data: a_data,
        rows: m,
        cols: k,
        ld: a_ld,
        trans: false,
    };
    let bp = Panel {
        data: b_data,
        rows: k,
        cols: n,
        ld: b_ld,
        trans: false,
    };
    // SAFETY: extents checked above; c_data exclusively borrowed.
    unsafe {
        gemm_driver::<f64>(
            ap,
            bp,
            c_data.as_mut_ptr(),
            c_ld,
            true,
            alpha,
            threads,
            simd_backend(),
        );
    }
}

/// C += α · A·Bᵀ over raw strided views, with C behind a bare pointer:
/// A is m×k at row stride `a_ld`, B is n×k at row stride `b_ld` (the
/// operand is its transpose), C is m×n at row stride `c_ld`.  This is
/// the rank-B panel update of the blocked TRSM (`solve_xlt_eq_b`):
/// X[:, right] −= X_blk · L[right, blk]ᵀ.  Always f64 — the
/// factorization layer is pinned like the rest of the quantizer core.
///
/// # Safety
/// `c` must be valid for `(m-1)*c_ld + n` elements with exclusive
/// access for the duration of the call; A/B slice extents are
/// debug-checked.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_nt_acc_ptr(
    m: usize,
    k: usize,
    n: usize,
    a_data: &[f64],
    a_ld: usize,
    b_data: &[f64],
    b_ld: usize,
    c: *mut f64,
    c_ld: usize,
    alpha: f64,
    threads: usize,
) {
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    debug_assert!(a_data.len() >= (m - 1) * a_ld + k);
    debug_assert!(b_data.len() >= (n - 1) * b_ld + k);
    let ap = Panel {
        data: a_data,
        rows: m,
        cols: k,
        ld: a_ld,
        trans: false,
    };
    let bp = Panel {
        data: b_data,
        rows: k,
        cols: n,
        ld: b_ld,
        trans: true,
    };
    gemm_driver::<f64>(ap, bp, c, c_ld, true, alpha, threads, simd_backend());
}

/// C += α · P·Pᵀ restricted to the lower triangle — the trailing-matrix
/// update of the right-looking blocked Cholesky.  P is m×k at row
/// stride `p_ld` (a contiguous scratch copy, so it never aliases C);
/// C is m×m at row stride `c_ld` behind a bare pointer.
///
/// The update is decomposed into a fixed GB×GB block grid over the
/// lower triangle (diagonal blocks computed in full — their strict
/// upper corner is scratch for the Cholesky caller and is documented
/// as clobbered).  Blocks are fanned over the worker pool with the
/// serial packed driver inside, so the set of per-element reduction
/// orders depends only on the shape — results are bit-for-bit
/// identical across thread counts.
///
/// # Safety
/// `c` must be valid for `(m-1)*c_ld + m` elements with exclusive
/// access for the duration of the call.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn syrk_lower_acc_ptr(
    m: usize,
    k: usize,
    p_data: &[f64],
    p_ld: usize,
    c: *mut f64,
    c_ld: usize,
    alpha: f64,
    threads: usize,
) {
    if m == 0 || k == 0 {
        return;
    }
    debug_assert!(p_data.len() >= (m - 1) * p_ld + k);
    const GB: usize = 64;
    let nb = m.div_ceil(GB);
    let pairs: Vec<(usize, usize)> = (0..nb)
        .flat_map(|bi| (0..=bi).map(move |bj| (bi, bj)))
        .collect();
    let cptr = AtomicPtr::new(c);
    let backend = simd_backend();
    parallel_ranges(pairs.len(), threads, |range| {
        let base = cptr.load(Ordering::Relaxed);
        for t in range {
            let (bi, bj) = pairs[t];
            let i0 = bi * GB;
            let i1 = ((bi + 1) * GB).min(m);
            let j0 = bj * GB;
            let j1 = ((bj + 1) * GB).min(m);
            // check-aliasing: this task owns the C tile
            // [i0..i1)×[j0..j1)
            crate::util::aliasing::claim_strided(
                base.wrapping_add(i0 * c_ld + j0) as *const f64,
                i1 - i0,
                j1 - j0,
                c_ld,
            );
            let ap = Panel {
                data: &p_data[i0 * p_ld..],
                rows: i1 - i0,
                cols: k,
                ld: p_ld,
                trans: false,
            };
            let bp = Panel {
                data: &p_data[j0 * p_ld..],
                rows: k,
                cols: j1 - j0,
                ld: p_ld,
                trans: true,
            };
            // SAFETY: block (bi, bj) owns the disjoint C region
            // [i0..i1)×[j0..j1) (bj ≤ bi, each pair appears once);
            // serial inner driver (threads = 1).
            unsafe {
                let ctile = base.add(i0 * c_ld + j0);
                gemm_driver::<f64>(ap, bp, ctile, c_ld, true, alpha, 1, backend);
            }
        }
    });
}

/// y = M · x
pub fn matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.cols, x.len());
    (0..m.rows).map(|i| super::dot(m.row(i), x)).collect()
}

/// y = Mᵀ · x
pub fn matvec_t(m: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(m.rows, x.len());
    let mut y = vec![0.0; m.cols];
    for i in 0..m.rows {
        super::axpy(x[i], m.row(i), &mut y);
    }
    y
}

/// diag(A · B) without forming the product — Alg. 4 needs diagonals of
/// several m×m products where only the diagonal is used.
pub fn diag_of_product(a: &Mat, b: &Mat) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols);
    (0..a.rows)
        .map(|i| {
            let mut s = 0.0;
            for k in 0..a.cols {
                s += a[(i, k)] * b[(k, i)];
            }
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian())
    }

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 33, 9), (64, 64, 64), (1, 7, 1)] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.sub(&c0).max_abs() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_matches_naive_nondivisible_tiles() {
        // shapes straddling every tile edge: MR=4, NR=8, MC=64, KC=256
        let mut rng = Rng::new(41);
        for (m, k, n) in [
            (5, 70, 9),     // nothing divides
            (63, 65, 67),   // just under/over MC
            (129, 257, 33), // crosses MC and KC boundaries
            (8, 600, 8),    // exact tile, K spans three KC blocks
            (66, 40, 1030), // crosses the NC panel edge
        ] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.sub(&c0).max_abs() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_degenerate_shapes() {
        let mut rng = Rng::new(42);
        // empty result dimensions
        let a = Mat::zeros(0, 7);
        let b = randm(7, 5, &mut rng);
        let c = matmul(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 5));
        // empty inner dimension → exact zeros
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let c = matmul(&a, &b);
        assert!(c.data.iter().all(|&x| x == 0.0));
        // single row / single column
        let a = randm(1, 200, &mut rng);
        let b = randm(200, 100, &mut rng);
        assert!(matmul(&a, &b).sub(&naive(&a, &b)).max_abs() < 1e-9);
        let b1 = randm(200, 1, &mut rng);
        assert!(matmul(&a, &b1).sub(&naive(&a, &b1)).max_abs() < 1e-9);
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // same tile decomposition and K order regardless of thread
        // count ⇒ bit-for-bit equality, not just tolerance
        let mut rng = Rng::new(43);
        let a = randm(150, 170, &mut rng);
        let b = randm(170, 130, &mut rng);
        let c1 = matmul_with_threads(&a, &b, 1);
        let c8 = matmul_with_threads(&a, &b, 8);
        assert_eq!(c1.data, c8.data, "threaded gemm must be deterministic");
        let p = randm(300, 90, &mut rng);
        let g1 = gram_with_threads(&p, 1);
        let g8 = gram_with_threads(&p, 8);
        assert_eq!(g1.data, g8.data, "threaded gram must be deterministic");
    }

    #[test]
    fn f32_threaded_matches_single_thread_bitwise() {
        let mut rng = Rng::new(48);
        let a = randm(150, 170, &mut rng);
        let b = randm(170, 130, &mut rng);
        let be = simd_backend();
        let c1 = matmul_f32_with(&a, &b, 1, be);
        let c8 = matmul_f32_with(&a, &b, 8, be);
        assert_eq!(c1.data, c8.data, "threaded f32 gemm must be deterministic");
    }

    #[test]
    fn f32_matmul_parity_nondivisible() {
        // f32 packed path vs the f64 kernel across tile-straddling
        // shapes: the KC-block f32 reduction bounds the relative error
        // to ~ε₃₂·√k ≈ 1e-6 on gaussian data
        let mut rng = Rng::new(50);
        for (m, k, n) in [(37, 41, 29), (63, 65, 67), (129, 257, 33), (66, 40, 1030)] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let c64 = matmul(&a, &b);
            let c32 = matmul_f32(&a, &b);
            let rel = c32.sub(&c64).frob_norm() / c64.frob_norm().max(1e-30);
            assert!(rel < 2e-5, "{m}x{k}x{n}: rel err {rel}");
        }
    }

    #[test]
    fn f32_prec_variants_parity() {
        // matmul_nt / gram / tn_acc through the f32 packed path
        let mut rng = Rng::new(51);
        let a = randm(70, 90, &mut rng);
        let b = randm(110, 90, &mut rng);
        let c64 = matmul_nt(&a, &b);
        let c32 = matmul_nt_prec(&a, &b, Precision::F32);
        assert!(c32.sub(&c64).frob_norm() / c64.frob_norm() < 2e-5);

        let p = randm(300, 90, &mut rng);
        let g64 = gram(&p);
        let g32 = gram_prec(&p, Precision::F32);
        assert!(g32.sub(&g64).frob_norm() / g64.frob_norm() < 2e-5);
        for i in 0..90 {
            for j in 0..i {
                assert_eq!(g32[(i, j)], g32[(j, i)], "f32 gram symmetry");
            }
        }

        let x = randm(120, 40, &mut rng);
        let y = randm(120, 50, &mut rng);
        let mut c = Mat::zeros(40, 50);
        matmul_tn_acc_prec(&x, &y, &mut c, Precision::F32);
        let expect = naive(&x.transpose(), &y);
        assert!(c.sub(&expect).frob_norm() / expect.frob_norm() < 2e-5);
    }

    #[test]
    fn simd_and_scalar_dispatch_agree_bitwise() {
        // every SIMD rung uses mul+add in the same per-lane order as
        // the scalar kernel, so the dispatch choice must not change a
        // single bit (on machines without SIMD this degenerates to
        // scalar == scalar)
        let mut rng = Rng::new(52);
        let a = randm(150, 170, &mut rng);
        let b = randm(170, 130, &mut rng);
        let auto = simd_backend();
        let c_auto = matmul_f32_with(&a, &b, 4, auto);
        let c_scalar = matmul_f32_with(&a, &b, 4, SimdBackend::Scalar);
        assert_eq!(
            c_auto.data,
            c_scalar.data,
            "f32 dispatch must be bit-identical (backend {auto:?})"
        );
    }

    #[test]
    fn f64_simd_and_scalar_dispatch_agree_bitwise() {
        let mut rng = Rng::new(53);
        let a = randm(129, 257, &mut rng);
        let b = randm(257, 66, &mut rng);
        let auto = simd_backend();
        let mut c_auto = Mat::zeros(129, 66);
        let mut c_scalar = Mat::zeros(129, 66);
        // SAFETY: each C is exactly rows×cols and exclusively borrowed.
        unsafe {
            gemm_driver::<f64>(
                Panel::normal(&a),
                Panel::normal(&b),
                c_auto.data.as_mut_ptr(),
                66,
                false,
                1.0,
                2,
                auto,
            );
            gemm_driver::<f64>(
                Panel::normal(&a),
                Panel::normal(&b),
                c_scalar.data.as_mut_ptr(),
                66,
                false,
                1.0,
                2,
                SimdBackend::Scalar,
            );
        }
        assert_eq!(
            c_auto.data,
            c_scalar.data,
            "f64 dispatch must be bit-identical (backend {auto:?})"
        );
    }

    #[test]
    fn prepacked_matches_pack_per_call_driver_bitwise() {
        // the prepacked panels are byte-identical to the per-call pack
        // and run the same tile sweep, so the product must match the
        // on-the-fly driver bit for bit — across tile-straddling
        // shapes, thread counts, dispatch rungs, and both precisions
        let mut rng = Rng::new(70);
        for (m, k, n) in [
            (5, 70, 9),
            (63, 65, 67),
            (129, 257, 33),
            (66, 40, 1030),
            (16, 512, 96),
        ] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let auto = simd_backend();
            for prec in [Precision::F64, Precision::F32] {
                let mut c_ref = Mat::zeros(m, n);
                // SAFETY: c_ref.data is exactly m×n, exclusively borrowed.
                unsafe {
                    gemm_driver_prec(
                        prec,
                        Panel::normal(&a),
                        Panel::normal(&b),
                        c_ref.data.as_mut_ptr(),
                        n,
                        false,
                        1.0,
                        3,
                        auto,
                    );
                }
                let pb = PrepackedB::pack(&b, prec);
                assert_eq!((pb.op_rows(), pb.op_cols()), (k, n));
                assert_eq!(pb.precision(), prec);
                let c1 = matmul_prepacked_with(&a, &pb, 1, auto);
                let c8 = matmul_prepacked_with(&a, &pb, 8, auto);
                let cs = matmul_prepacked_with(&a, &pb, 4, SimdBackend::Scalar);
                assert_eq!(
                    c_ref.data,
                    c1.data,
                    "{m}x{k}x{n} {} prepack vs on-the-fly",
                    prec.name()
                );
                assert_eq!(c1.data, c8.data, "{m}x{k}x{n} threads");
                assert_eq!(c1.data, cs.data, "{m}x{k}x{n} scalar rung");
            }
        }
    }

    #[test]
    fn prepacked_nt_matches_public_path() {
        // above the packed threshold matmul_nt routes through the
        // driver, so the prepacked transpose view must be bit-identical
        // to the public entry end to end
        let mut rng = Rng::new(71);
        let a = randm(70, 90, &mut rng);
        let w = randm(110, 90, &mut rng);
        let pb = PrepackedB::pack_nt(&w, Precision::F64);
        assert_eq!((pb.op_rows(), pb.op_cols()), (90, 110));
        assert_eq!(matmul_prepacked(&a, &pb).data, matmul_nt(&a, &w).data);
        let pb32 = PrepackedB::pack_nt(&w, Precision::F32);
        assert_eq!(
            matmul_prepacked(&a, &pb32).data,
            matmul_nt_prec(&a, &w, Precision::F32).data
        );
        assert!(pb32.bytes() < pb.bytes());
    }

    #[test]
    fn prepacked_rows_independent_of_batch() {
        // the serving batcher invariant: row i of C depends only on
        // row i of A, so embedding the same rows in a bigger batch
        // must reproduce them bit for bit
        let mut rng = Rng::new(72);
        let w = randm(40, 64, &mut rng);
        let pb = PrepackedB::pack_nt(&w, Precision::F64);
        let small = randm(3, 64, &mut rng);
        let mut big = randm(100, 64, &mut rng);
        for r in 0..3 {
            big.row_mut(10 + r).copy_from_slice(small.row(r));
        }
        let c_small = matmul_prepacked(&small, &pb);
        let c_big = matmul_prepacked(&big, &pb);
        for r in 0..3 {
            assert_eq!(c_small.row(r), c_big.row(10 + r), "row {r}");
        }
    }

    #[test]
    fn prepacked_degenerate_shapes() {
        let mut rng = Rng::new(73);
        // empty inner dimension → exact zeros
        let pb = PrepackedB::pack(&Mat::zeros(0, 4), Precision::F64);
        let c = matmul_prepacked(&Mat::zeros(3, 0), &pb);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
        // empty output rows
        let b = randm(7, 5, &mut rng);
        let pb = PrepackedB::pack(&b, Precision::F64);
        let c = matmul_prepacked(&Mat::zeros(0, 7), &pb);
        assert_eq!((c.rows, c.cols), (0, 5));
    }

    /// Owned storage behind a [`CodedPart`] view, plus the eager
    /// dequant the coded path must reproduce bit for bit.
    struct OwnedPart {
        z: Vec<i32>,
        t: Vec<f64>,
        gammas: Vec<f64>,
        alphas: Vec<f64>,
        rows: usize,
        cols: usize,
    }

    impl OwnedPart {
        fn random(rows: usize, cols: usize, rng: &mut Rng) -> OwnedPart {
            OwnedPart {
                z: (0..rows * cols)
                    .map(|_| (rng.gaussian() * 4.0).round() as i32)
                    .collect(),
                t: (0..rows).map(|_| rng.gaussian().abs() + 0.1).collect(),
                gammas: (0..cols).map(|_| rng.gaussian().abs() + 0.1).collect(),
                alphas: (0..cols).map(|_| rng.gaussian().abs() + 0.1).collect(),
                rows,
                cols,
            }
        }

        fn view(&self) -> CodedPart<'_> {
            CodedPart {
                z: &self.z,
                t: &self.t,
                gammas: &self.gammas,
                alphas: &self.alphas,
                rows: self.rows,
                cols: self.cols,
            }
        }

        fn dequant(&self) -> Mat {
            Mat::from_fn(self.rows, self.cols, |i, j| {
                ((self.t[i] * f64::from(self.z[i * self.cols + j])) * self.gammas[j])
                    * self.alphas[j]
            })
        }
    }

    /// Vertical stack of the parts' eager dequants — the fused
    /// operand the coded panel represents transposed.
    fn stack_dequant(parts: &[OwnedPart]) -> Mat {
        let cols = parts[0].cols;
        let rows = parts.iter().map(|p| p.rows).sum();
        let mats: Vec<Mat> = parts.iter().map(|p| p.dequant()).collect();
        Mat::from_fn(rows, cols, |i, j| {
            let mut i = i;
            for (p, m) in parts.iter().zip(&mats) {
                if i < p.rows {
                    return m[(i, j)];
                }
                i -= p.rows;
            }
            unreachable!()
        })
    }

    #[test]
    fn coded_matches_prepacked_over_dequant_bitwise() {
        // the correctness pin of the coded path: decode-inside-pack
        // computes the same f64 dequant expression at the same panel
        // position as eager dequant + pack_nt, and runs the same tile
        // sweep — so equality is bitwise, across tile-straddling
        // shapes, thread counts, dispatch rungs, and both precisions
        let mut rng = Rng::new(80);
        for (m, k, n) in [
            (5, 70, 9),
            (63, 65, 67),
            (129, 257, 33),
            (66, 40, 1030),
            (16, 512, 96),
            (1, 512, 512),
        ] {
            let a = randm(m, k, &mut rng);
            let part = OwnedPart::random(n, k, &mut rng);
            let w = part.dequant();
            let auto = simd_backend();
            for prec in [Precision::F64, Precision::F32] {
                let pb = PrepackedB::pack_nt(&w, prec);
                let cp = CodedPanel::pack_nt_parts(&[part.view()], prec).unwrap();
                assert_eq!((cp.op_rows(), cp.op_cols()), (k, n));
                assert_eq!(cp.precision(), prec);
                let c_ref = matmul_prepacked_with(&a, &pb, 3, auto);
                let c1 = matmul_coded_with(&a, &cp, 1, auto);
                let c8 = matmul_coded_with(&a, &cp, 8, auto);
                let cs = matmul_coded_with(&a, &cp, 4, SimdBackend::Scalar);
                assert_eq!(
                    c_ref.data,
                    c1.data,
                    "{m}x{k}x{n} {} coded vs prepacked-dequant",
                    prec.name()
                );
                assert_eq!(c1.data, c8.data, "{m}x{k}x{n} threads");
                assert_eq!(c1.data, cs.data, "{m}x{k}x{n} scalar rung");
            }
        }
    }

    #[test]
    fn coded_multipart_fused_matches_stacked_dequant() {
        // fused projections stack parts whose row counts need not be
        // NR-multiples, so part boundaries land mid-sub-panel and the
        // decode must switch γ/α tables per column
        let mut rng = Rng::new(81);
        let k = 70;
        let parts = [
            OwnedPart::random(13, k, &mut rng),
            OwnedPart::random(11, k, &mut rng),
            OwnedPart::random(10, k, &mut rng),
        ];
        let w = stack_dequant(&parts);
        let a = randm(9, k, &mut rng);
        let views: Vec<CodedPart> = parts.iter().map(|p| p.view()).collect();
        for prec in [Precision::F64, Precision::F32] {
            let pb = PrepackedB::pack_nt(&w, prec);
            let cp = CodedPanel::pack_nt_parts(&views, prec).unwrap();
            assert_eq!((cp.op_rows(), cp.op_cols()), (k, 34));
            assert_eq!(
                matmul_prepacked(&a, &pb).data,
                matmul_coded(&a, &cp).data,
                "{} multi-part",
                prec.name()
            );
        }
    }

    #[test]
    fn coded_extreme_codes_roundtrip_bitwise() {
        // i32 extremes force 32-bit groups through the zigzag packer;
        // the panel must still reproduce eager dequant bit for bit
        let mut rng = Rng::new(82);
        let (k, n) = (40, 17);
        let mut part = OwnedPart::random(n, k, &mut rng);
        part.z[0] = i32::MAX;
        part.z[1] = i32::MIN;
        part.z[k] = -1;
        let w = part.dequant();
        let pb = PrepackedB::pack_nt(&w, Precision::F64);
        let cp = CodedPanel::pack_nt_parts(&[part.view()], Precision::F64).unwrap();
        let a = randm(3, k, &mut rng);
        assert_eq!(matmul_prepacked(&a, &pb).data, matmul_coded(&a, &cp).data);
    }

    #[test]
    fn coded_degenerate_shapes() {
        let mut rng = Rng::new(83);
        // empty inner dimension → exact zeros of the right shape
        let part = OwnedPart::random(4, 0, &mut rng);
        let cp = CodedPanel::pack_nt_parts(&[part.view()], Precision::F64).unwrap();
        let c = matmul_coded(&Mat::zeros(3, 0), &cp);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
        // empty output rows
        let part = OwnedPart::random(5, 7, &mut rng);
        let cp = CodedPanel::pack_nt_parts(&[part.view()], Precision::F64).unwrap();
        let c = matmul_coded(&Mat::zeros(0, 7), &cp);
        assert_eq!((c.rows, c.cols), (0, 5));
    }

    #[test]
    fn coded_rejects_inconsistent_parts() {
        let mut rng = Rng::new(84);
        let good = OwnedPart::random(6, 10, &mut rng);
        assert!(CodedPanel::pack_nt_parts(&[], Precision::F64).is_err());
        // truncated code plane
        let mut bad = good.view();
        bad.z = &good.z[..good.z.len() - 1];
        assert!(CodedPanel::pack_nt_parts(&[bad], Precision::F64).is_err());
        // wrong row-rescaler count
        let mut bad = good.view();
        bad.t = &good.t[..good.t.len() - 1];
        assert!(CodedPanel::pack_nt_parts(&[bad], Precision::F64).is_err());
        // wrong column-rescaler counts
        let mut bad = good.view();
        bad.gammas = &good.gammas[..good.gammas.len() - 1];
        assert!(CodedPanel::pack_nt_parts(&[bad], Precision::F64).is_err());
        let mut bad = good.view();
        bad.alphas = &good.alphas[..good.alphas.len() - 1];
        assert!(CodedPanel::pack_nt_parts(&[bad], Precision::F64).is_err());
        // parts with mismatched storage widths can't stack
        let other = OwnedPart::random(6, 11, &mut rng);
        assert!(
            CodedPanel::pack_nt_parts(&[good.view(), other.view()], Precision::F64).is_err()
        );
    }

    #[test]
    fn coded_bytes_near_code_plane_size() {
        // small-magnitude codes bit-pack far below the eager panels;
        // the side information (f64 rescalers per row/col) is the floor
        let mut rng = Rng::new(85);
        let part = OwnedPart::random(256, 512, &mut rng);
        let cp = CodedPanel::pack_nt_parts(&[part.view()], Precision::F64).unwrap();
        let pb = PrepackedB::pack_nt(&part.dequant(), Precision::F64);
        assert!(
            cp.bytes() * 4 < pb.bytes(),
            "coded {} vs eager {} bytes",
            cp.bytes(),
            pb.bytes()
        );
    }

    #[test]
    fn matmul_nt_matches() {
        let mut rng = Rng::new(2);
        let a = randm(13, 21, &mut rng);
        let b = randm(8, 21, &mut rng);
        let c = matmul_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.sub(&c0).max_abs() < 1e-9);
        // large enough to hit the packed transposed-B path
        let a = randm(70, 90, &mut rng);
        let b = randm(110, 90, &mut rng);
        let c = matmul_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }

    #[test]
    fn gram_is_ata() {
        let mut rng = Rng::new(3);
        let a = randm(40, 12, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
        // symmetry
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn gram_packed_path_matches_and_is_symmetric() {
        // big enough for the blocked symmetric sweep, non-divisible n
        let mut rng = Rng::new(44);
        let a = randm(200, 70, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
        for i in 0..70 {
            for j in 0..70 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
        // and across the GB=64 block edge with >1 block in each dim
        let a = randm(150, 130, &mut rng);
        let g = gram(&a);
        let g0 = naive(&a.transpose(), &a);
        assert!(g.sub(&g0).max_abs() < 1e-9);
    }

    #[test]
    fn gram_acc_accumulates() {
        let mut rng = Rng::new(45);
        let a = randm(120, 40, &mut rng);
        let b = randm(80, 40, &mut rng);
        let mut acc = Mat::zeros(40, 40);
        gram_acc(&a, &mut acc);
        gram_acc(&b, &mut acc);
        let expect = naive(&a.transpose(), &a).add(&naive(&b.transpose(), &b));
        assert!(acc.sub(&expect).max_abs() < 1e-9);
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(acc[(i, j)], acc[(j, i)]);
            }
        }
    }

    #[test]
    fn matmul_tn_acc_matches() {
        let mut rng = Rng::new(46);
        for (r, m, n) in [(30, 6, 8), (120, 40, 50)] {
            let x = randm(r, m, &mut rng);
            let y = randm(r, n, &mut rng);
            let mut c = Mat::zeros(m, n);
            matmul_tn_acc(&x, &y, &mut c);
            matmul_tn_acc(&x, &y, &mut c); // accumulate twice
            let expect = naive(&x.transpose(), &y).scale(2.0);
            assert!(c.sub(&expect).max_abs() < 1e-9, "{r}x{m}x{n}");
        }
    }

    #[test]
    fn strided_acc_matches_axpy_reference() {
        // emulate the ZSIC deferred update: C[:, :blo] -= S · L-block
        let mut rng = Rng::new(47);
        let (a, bw, blo, ld) = (40, 16, 50, 64);
        let s = randm(a, ld, &mut rng); // only first bw cols used
        let l = randm(bw, blo, &mut rng);
        let mut c = randm(a, blo, &mut rng);
        let mut c_ref = c.clone();
        for r in 0..a {
            for k in 0..bw {
                let coeff = s[(r, k)];
                for j in 0..blo {
                    c_ref[(r, j)] -= coeff * l[(k, j)];
                }
            }
        }
        gemm_acc_strided(
            a, bw, blo, &s.data, ld, &l.data, blo, &mut c.data, blo, -1.0, 2,
        );
        assert!(c.sub(&c_ref).max_abs() < 1e-9);
    }

    #[test]
    fn nt_acc_ptr_matches_axpy_reference() {
        // the blocked-TRSM panel update: C -= A · Bᵀ on strided views
        let mut rng = Rng::new(60);
        let (m, k, n, b_ld) = (37, 16, 90, 40); // B is n×k inside a wider stride
        let a = randm(m, k, &mut rng);
        let bfull = randm(n, b_ld, &mut rng);
        let mut c = randm(m, n, &mut rng);
        let mut c_ref = c.clone();
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..k {
                    s += a[(i, t)] * bfull[(j, t)];
                }
                c_ref[(i, j)] -= s;
            }
        }
        // SAFETY: c.data is exactly m×n and exclusively borrowed.
        unsafe {
            gemm_nt_acc_ptr(
                m,
                k,
                n,
                &a.data,
                k,
                &bfull.data,
                b_ld,
                c.data.as_mut_ptr(),
                n,
                -1.0,
                2,
            );
        }
        assert!(c.sub(&c_ref).max_abs() < 1e-9);
    }

    #[test]
    fn syrk_lower_acc_ptr_matches_reference_and_is_deterministic() {
        // trailing-update shape: lower-triangle C -= P·Pᵀ across the
        // GB=64 block edge, upper-of-diagonal-block clobber tolerated
        let mut rng = Rng::new(61);
        let (m, k) = (150, 48);
        let p = randm(m, k, &mut rng);
        let c0 = randm(m, m, &mut rng);
        let run = |threads: usize| {
            let mut c = c0.clone();
            // SAFETY: c.data is exactly m×m and exclusively borrowed.
            unsafe {
                syrk_lower_acc_ptr(m, k, &p.data, k, c.data.as_mut_ptr(), m, -1.0, threads);
            }
            c
        };
        let c = run(4);
        let ppt = naive(&p, &p.transpose());
        for i in 0..m {
            for j in 0..=i {
                let expect = c0[(i, j)] - ppt[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
        // strictly-upper elements outside diagonal blocks untouched
        assert_eq!(c[(0, 100)], c0[(0, 100)]);
        assert_eq!(c[(10, 140)], c0[(10, 140)]);
        // bit-for-bit across thread counts
        assert_eq!(run(1).data, run(8).data);
    }

    #[test]
    fn matvec_both_ways() {
        let mut rng = Rng::new(4);
        let m = randm(6, 9, &mut rng);
        let x: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let y = matvec(&m, &x);
        let y0 = naive(&m, &Mat::from_vec(9, 1, x.clone()));
        for i in 0..6 {
            assert!((y[i] - y0[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let w = matvec_t(&m, &z);
        let w0 = naive(&m.transpose(), &Mat::from_vec(6, 1, z));
        for j in 0..9 {
            assert!((w[j] - w0[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn diag_of_product_matches() {
        let mut rng = Rng::new(5);
        let a = randm(7, 11, &mut rng);
        let b = randm(11, 7, &mut rng);
        let d = diag_of_product(&a, &b);
        let full = matmul(&a, &b);
        for i in 0..7 {
            assert!((d[i] - full[(i, i)]).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_path_consistent() {
        // big enough to trigger the threaded path
        let mut rng = Rng::new(6);
        let a = randm(128, 96, &mut rng);
        let b = randm(96, 80, &mut rng);
        let c = matmul(&a, &b);
        let c0 = naive(&a, &b);
        assert!(c.sub(&c0).max_abs() < 1e-9);
    }

    #[test]
    fn env_precision_packed_parity() {
        // runs at whatever WATERSIC_PRECISION selects (the rust-f32 CI
        // job sets f32) on a shape past the packed threshold, checked
        // against the f64 reference — under f64 this is exact, under
        // f32 it exercises the environment-driven path at scale
        let mut rng = Rng::new(54);
        let a = randm(80, 120, &mut rng);
        let b = randm(120, 90, &mut rng);
        let c = matmul_prec(&a, &b, Precision::from_env());
        let c64 = matmul(&a, &b);
        let rel = c.sub(&c64).frob_norm() / c64.frob_norm();
        assert!(rel < 2e-5, "env-precision gemm drifted: {rel}");
    }

    #[test]
    fn precision_env_and_names() {
        assert_eq!(Precision::F32.name(), "f32");
        assert_eq!(Precision::F64.name(), "f64");
        // from_env is cached and must be one of the two modes
        let p = Precision::from_env();
        assert!(p == Precision::F32 || p == Precision::F64);
        assert_eq!(p, Precision::from_env());
        // the selected backend is stable across calls
        assert_eq!(simd_backend(), simd_backend());
    }
}
