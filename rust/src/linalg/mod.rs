//! Dense linear-algebra substrate (f64, row-major).  No BLAS/LAPACK is
//! available offline, so everything the pipeline needs is implemented
//! here: packed blocked gemm, a blocked pool-parallel Cholesky and
//! TRSM (both routed through the packed driver and bit-for-bit
//! thread-count deterministic), a Jacobi symmetric eigensolver (for
//! the waterfilling bound), and streaming statistics.

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod stats;

pub use chol::SpdFactor;

use anyhow::{bail, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Construct a diagonal matrix from a vector.
    pub fn diag_from(v: &[f64]) -> Mat {
        let mut m = Mat::zeros(v.len(), v.len());
        for (i, &x) in v.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    pub fn trace(&self) -> f64 {
        self.diag().iter().sum()
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Element-wise (Hadamard) product — used by the Γ-step of Alg. 4.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    /// Add `delta` to the diagonal in place (Hessian damping).
    pub fn add_diag(&mut self, delta: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += delta;
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Extract the sub-matrix with the given rows and cols (dead-feature
    /// erasure builds the reduced system with this).
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Mat {
        let mut m = Mat::zeros(rows.len(), cols.len());
        for (ii, &i) in rows.iter().enumerate() {
            for (jj, &j) in cols.iter().enumerate() {
                m[(ii, jj)] = self[(i, j)];
            }
        }
        m
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    pub fn assert_square(&self) -> Result<usize> {
        if self.rows != self.cols {
            bail!("expected square matrix, got {}x{}", self.rows, self.cols);
        }
        Ok(self.rows)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m[(1, 2)], 5.0);
        let t = m.transpose();
        assert_eq!(t[(2, 1)], 5.0);
        assert_eq!(t.rows, 3);
    }

    #[test]
    fn submatrix_picks() {
        let m = Mat::from_fn(4, 4, |i, j| (10 * i + j) as f64);
        let s = m.submatrix(&[1, 3], &[0, 2]);
        assert_eq!(s.data, vec![10.0, 12.0, 30.0, 32.0]);
    }

    #[test]
    fn hadamard_and_trace() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![2.0, 0.5, 1.0, 2.0]);
        let h = a.hadamard(&b);
        assert_eq!(h.data, vec![2.0, 1.0, 3.0, 8.0]);
        assert_eq!(a.trace(), 5.0);
    }
}
