//! Streaming statistics helpers: means, variances, medians, empirical
//! CDF distances (for the Fig. 11 Gaussianity study), and histograms.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean absolute deviation from the mean (Laplace scale estimator is
/// b̂ = MAD_mean).
pub fn mean_abs_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    mean(&xs.iter().map(|x| (x - m).abs()).collect::<Vec<_>>())
}

/// Standard normal CDF via erf approximation (Abramowitz–Stegun 7.1.26,
/// |err| < 1.5e-7 — plenty for KS distances reported to 3 decimals).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

pub fn erf(x: f64) -> f64 {
    let sign = x.signum();
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736
                + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Laplace(μ=mu, b) CDF.
pub fn laplace_cdf(x: f64, mu: f64, b: f64) -> f64 {
    let z = (x - mu) / b;
    if z < 0.0 {
        0.5 * z.exp()
    } else {
        1.0 - 0.5 * (-z).exp()
    }
}

/// Kolmogorov–Smirnov distance between the empirical CDF of `xs` and a
/// model CDF.
pub fn ks_distance(xs: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in v.iter().enumerate() {
        let f = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((f - lo).abs()).max((hi - f).abs());
    }
    d
}

/// KS distance to the best-fit (moment-matched) Gaussian.
pub fn ks_gaussian(xs: &[f64]) -> f64 {
    let mu = mean(xs);
    let sd = variance(xs).sqrt().max(1e-300);
    ks_distance(xs, |x| normal_cdf((x - mu) / sd))
}

/// KS distance to the best-fit Laplace (median/MAD estimators).
pub fn ks_laplace(xs: &[f64]) -> f64 {
    let mu = median(xs);
    let b = mean(&xs.iter().map(|x| (x - mu).abs()).collect::<Vec<_>>())
        .max(1e-300);
    ks_distance(xs, |x| laplace_cdf(x, mu, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-5);
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn ks_discriminates_gaussian_from_laplace() {
        let mut rng = Rng::new(33);
        let gauss: Vec<f64> = (0..20_000).map(|_| rng.gaussian()).collect();
        let lap: Vec<f64> = (0..20_000).map(|_| rng.laplace()).collect();
        // Gaussian sample: close to Gaussian fit, far from it for Laplace.
        assert!(ks_gaussian(&gauss) < 0.02, "{}", ks_gaussian(&gauss));
        assert!(ks_gaussian(&lap) > ks_gaussian(&gauss));
        assert!(ks_laplace(&lap) < ks_laplace(&gauss));
    }

    #[test]
    fn ks_distance_of_exact_cdf_is_small() {
        // uniform sample vs uniform CDF
        let mut rng = Rng::new(34);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.uniform()).collect();
        let d = ks_distance(&xs, |x| x.clamp(0.0, 1.0));
        assert!(d < 0.01, "{d}");
    }
}
