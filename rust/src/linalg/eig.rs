//! Cyclic Jacobi eigensolver for symmetric matrices — needed for the
//! waterfilling bound (eigenvalues of Σ_X) and for conditioning
//! diagnostics.  O(n³) per sweep, converges quadratically; plenty for
//! the n ≤ 1024 covariances in this system.

use super::Mat;

/// Eigen-decomposition of a symmetric matrix: returns (eigenvalues
/// descending, eigenvectors as columns of V so that A = V Λ Vᵀ).
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mut m = a.clone();
    // symmetrize defensively
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    let mut poisoned = false;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        // a non-finite off-diagonal mass (NaN-poisoned input) can never
        // converge — stop sweeping instead of burning max_sweeps O(n³)
        // passes of NaN arithmetic, and poison the whole spectrum below
        // so the caller sees NaN rather than the untouched (finite but
        // meaningless) diagonal
        if !off.is_finite() {
            poisoned = true;
            break;
        }
        if off.sqrt() < 1e-12 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n)
        .map(|i| (if poisoned { f64::NAN } else { m[(i, i)] }, i))
        .collect();
    // total_cmp: `partial_cmp().unwrap()` panicked on any non-finite
    // diagonal (e.g. a NaN-poisoned covariance reaching the
    // waterfilling bound); the IEEE total order sorts NaN after every
    // finite value instead
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let vals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vv = Mat::zeros(n, n);
    for (new_j, (_, old_j)) in pairs.iter().enumerate() {
        for i in 0..n {
            vv[(i, new_j)] = v[(i, *old_j)];
        }
    }
    (vals, vv)
}

/// Eigenvalues only (descending).
pub fn eigvals(a: &Mat) -> Vec<f64> {
    eigh(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram, matmul};
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::diag_from(&[3.0, 1.0, 2.0]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(21);
        for n in [2, 5, 17, 32] {
            let g = gram(&Mat::from_fn(n + 3, n, |_, _| rng.gaussian()));
            let (vals, v) = eigh(&g);
            // A = V diag(vals) Vᵀ
            let re = matmul(&matmul(&v, &Mat::diag_from(&vals)), &v.transpose());
            assert!(re.sub(&g).max_abs() < 1e-8, "n={n}");
            // VᵀV = I
            let vtv = matmul(&v.transpose(), &v);
            assert!(vtv.sub(&Mat::eye(n)).max_abs() < 1e-9);
            // PSD source → nonnegative eigenvalues (tolerance)
            assert!(vals.iter().all(|&x| x > -1e-9));
            // descending
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn nan_input_returns_without_panicking() {
        // regression: the eigenpair sort used partial_cmp().unwrap(),
        // which panicked the moment a NaN reached the diagonal — a
        // NaN-poisoned covariance hitting the waterfilling bound took
        // the whole experiment down instead of reporting a NaN rate
        let mut a = Mat::from_fn(4, 4, |i, j| ((i + j) as f64).cos());
        // symmetrize, then poison one entry
        for i in 0..4 {
            for j in 0..i {
                let avg = 0.5 * (a[(i, j)] + a[(j, i)]);
                a[(i, j)] = avg;
                a[(j, i)] = avg;
            }
        }
        a[(1, 2)] = f64::NAN;
        a[(2, 1)] = f64::NAN;
        a[(2, 2)] = f64::NAN;
        let vals = eigvals(&a);
        assert_eq!(vals.len(), 4, "must return a full spectrum");
        // the poison propagates as NaN values, not as a panic
        assert!(vals.iter().any(|v| v.is_nan()));
        let (_, v) = eigh(&a);
        assert_eq!((v.rows, v.cols), (4, 4));

        // NaN only OFF the diagonal: the sweep bail-out must poison
        // the spectrum, not report the untouched finite diagonal as
        // plausible eigenvalues
        let mut b = Mat::diag_from(&[3.0, 2.0, 1.0]);
        b[(0, 2)] = f64::NAN;
        b[(2, 0)] = f64::NAN;
        let vals = eigvals(&b);
        assert_eq!(vals.len(), 3);
        assert!(
            vals.iter().all(|v| v.is_nan()),
            "off-diagonal poison must not yield a finite spectrum: {vals:?}"
        );
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Rng::new(22);
        let n = 12;
        let mut g = gram(&Mat::from_fn(2 * n, n, |_, _| rng.gaussian()));
        g.add_diag(0.1);
        let vals = eigvals(&g);
        let tr: f64 = vals.iter().sum();
        assert!((tr - g.trace()).abs() < 1e-8);
        let logdet: f64 = vals.iter().map(|x| x.ln()).sum();
        let ld = crate::linalg::chol::spd_logdet(&g).unwrap();
        assert!((logdet - ld).abs() < 1e-8);
    }
}
