//! Cholesky decomposition and triangular solves — the backbone of ZSIC
//! (Σ = LLᵀ) and of the drift-corrected target ŷ = (WΣ_{X,X̂}+Σ_Δ)(L̂ᵀ)⁻¹.
//!
//! Both entry points are **blocked** and routed through the packed gemm
//! driver (PR 1/2), because at Llama-scale widths the factorization
//! front-end — not ZSIC itself — dominates a rate-targeted layer:
//!
//! * [`cholesky`] is a right-looking blocked factorization: a serial
//!   `CHOL_BLOCK`-wide panel factorization, a pool-parallel row-wise
//!   panel TRSM, and a trailing-matrix update `C −= P·Pᵀ` fanned over
//!   the worker pool as a fixed grid of lower-triangle blocks
//!   (`gemm::syrk_lower_acc_ptr`), each computed by the serial packed
//!   driver;
//! * [`solve_xlt_eq_b`] is a blocked TRSM: an in-place diagonal-block
//!   forward substitution with rows distributed over the pool (no
//!   per-row allocation), then one packed rank-B panel update
//!   `X[:, right] −= X_blk · L[right, blk]ᵀ` per block
//!   (`gemm::gemm_nt_acc_ptr`).
//!
//! Determinism: every decomposition (panel edges, the trailing block
//! grid, the packed driver's K order) depends only on the problem
//! shape, never on scheduling — results are bit-for-bit identical
//! across thread counts (tested).  For n ≤ `CHOL_BLOCK` the blocked
//! paths degenerate to the single-block substitutions and are
//! bit-identical to the seed implementations
//! ([`cholesky_unblocked`] / [`solve_xlt_eq_b_rowwise`], kept as
//! references for tests and benches).
//!
//! [`SpdFactor`] carries a factorization across solves so hot callers
//! (the Alg. 4 Γ-step, the `PreparedLayer` front-end cache) factor
//! once and reuse; a thread-local factorization counter makes "how
//! many times did we factor" test-visible.

use std::sync::atomic::{AtomicPtr, Ordering};

use anyhow::{bail, Result};

use super::Mat;
use crate::util::threadpool::parallel_ranges;

/// Panel width of the blocked factorization and the blocked TRSM
/// (matches the packed driver's symmetric block edge).
pub const CHOL_BLOCK: usize = 64;

thread_local! {
    /// Factorizations *initiated* by this thread (the blocked body may
    /// fan chunks to the pool, but the entry call runs here).
    /// Thread-local so concurrently running tests never race each
    /// other's deltas; the prepare-once regression tests and the bench
    /// counter read it immediately around a call.
    static FACTORIZATIONS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Factorizations initiated by *any* thread since process start.  The
/// streaming pipeline prepares layers on a producer thread, so its
/// prepare-once accounting is invisible to the thread-local counter;
/// this one is for single-test binaries and benches only — inside
/// `cargo test`'s threaded harness concurrent tests race its deltas.
static FACTORIZATIONS_GLOBAL: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// Number of Cholesky factorizations initiated by the calling thread
/// since it started (test/bench visibility for the prepare-once cache).
pub fn factorization_count() -> usize {
    FACTORIZATIONS.with(|c| c.get())
}

/// Process-wide factorization count (see [`factorization_count`] for
/// the thread-local variant and the caveat on when each is safe).
pub fn factorization_count_global() -> usize {
    FACTORIZATIONS_GLOBAL.load(Ordering::Relaxed)
}

fn chol_threads(n: usize) -> usize {
    crate::linalg::gemm::threads_for(n * n * n / 3)
}

fn trsm_threads(rows: usize, n: usize) -> usize {
    crate::linalg::gemm::threads_for(rows * n * n)
}

/// Lower-triangular Cholesky factor of a PSD matrix: A = L·Lᵀ.
/// Fails if a pivot goes non-positive (caller should damp / erase dead
/// features first — exactly the paper's workflow).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    cholesky_with_threads(a, chol_threads(a.rows))
}

/// [`cholesky`] with an explicit thread count — bit-for-bit identical
/// across thread counts (see module docs); exposed for determinism
/// tests and tuning.
pub fn cholesky_with_threads(a: &Mat, threads: usize) -> Result<Mat> {
    let n = a.assert_square()?;
    FACTORIZATIONS.with(|c| c.set(c.get() + 1));
    FACTORIZATIONS_GLOBAL.fetch_add(1, Ordering::Relaxed);
    let mut l = a.clone();
    let mut panel: Vec<f64> = Vec::new();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + CHOL_BLOCK).min(n);
        factor_diag_block(&mut l, k0, k1)?;
        if k1 < n {
            trsm_chol_panel(&mut l, k0, k1, threads);
            // trailing update l[k1.., k1..] −= P·Pᵀ with
            // P = l[k1.., k0..k1], copied into a contiguous scratch so
            // the packed driver never aliases its own output
            let bw = k1 - k0;
            let mt = n - k1;
            panel.resize(mt * bw, 0.0);
            for (r, i) in (k1..n).enumerate() {
                panel[r * bw..(r + 1) * bw].copy_from_slice(&l.data[i * n + k0..i * n + k1]);
            }
            // SAFETY: l.data is exclusively borrowed; the trailing
            // square starts at (k1, k1) and fits inside it.
            unsafe {
                crate::linalg::gemm::syrk_lower_acc_ptr(
                    mt,
                    bw,
                    &panel,
                    bw,
                    l.data.as_mut_ptr().add(k1 * n + k1),
                    n,
                    -1.0,
                    threads,
                );
            }
        }
        k0 = k1;
    }
    // the factorization only ever writes the lower triangle; clear the
    // strict upper (input copies + diagonal-block scratch)
    for i in 0..n {
        for j in i + 1..n {
            l[(i, j)] = 0.0;
        }
    }
    Ok(l)
}

/// In-place factorization of the diagonal block [k0, k1): by the time
/// this runs, the trailing updates of all previous panels have been
/// applied, so only within-block terms remain.
fn factor_diag_block(l: &mut Mat, k0: usize, k1: usize) -> Result<()> {
    for i in k0..k1 {
        for j in k0..=i {
            let mut s = l[(i, j)];
            for t in k0..j {
                s -= l[(i, t)] * l[(j, t)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    bail!(
                        "cholesky pivot {i} non-positive ({s:.3e}); \
                         damp or erase dead features"
                    );
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(())
}

/// Panel TRSM of the blocked factorization: rows k1..n of columns
/// [k0, k1) solve against the freshly factored diagonal block, one row
/// per task over the pool (row-serial arithmetic ⇒ deterministic).
fn trsm_chol_panel(l: &mut Mat, k0: usize, k1: usize, threads: usize) {
    let n = l.cols;
    let rows = n - k1;
    let ptr = AtomicPtr::new(l.data.as_mut_ptr());
    parallel_ranges(rows, threads, |range| {
        let base = ptr.load(Ordering::Relaxed);
        for off in range {
            let i = k1 + off;
            // check-aliasing: row i, columns [k0, k1) is this task's
            // exclusive write-set (rows k0..k1 are only read)
            crate::util::aliasing::claim(base.wrapping_add(i * n + k0) as *const f64, k1 - k0);
            // SAFETY: row i is owned by this task; rows k0..k1 (the
            // factored diagonal block) are read-only during this phase
            // and disjoint from every written row (j < k1 ≤ i).
            let row = unsafe { std::slice::from_raw_parts_mut(base.add(i * n), k1) };
            for j in k0..k1 {
                // SAFETY: row j < k1 ≤ i lies in the already-factored
                // diagonal block — read-only this phase, never aliased
                // by any task's written row i.
                let lj = unsafe { std::slice::from_raw_parts(base.add(j * n), j + 1) };
                let mut s = row[j];
                for t in k0..j {
                    s -= row[t] * lj[t];
                }
                row[j] = s / lj[j];
            }
        }
    });
}

/// Seed single-level factorization, kept verbatim as the reference the
/// blocked path is tested (and benchmarked) against.
pub fn cholesky_unblocked(a: &Mat) -> Result<Mat> {
    let n = a.assert_square()?;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    bail!(
                        "cholesky pivot {i} non-positive ({s:.3e}); \
                         damp or erase dead features"
                    );
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L·x = b with L lower-triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            s -= lrow[k] * x[k];
        }
        x[i] = s / lrow[i];
    }
    x
}

/// Solve Lᵀ·x = b with L lower-triangular (back substitution).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve X·Lᵀ = B row-wise, i.e. X = B·(Lᵀ)⁻¹.  This is the exact
/// operation in eq. (17)/(18): ŷ = (…)·(L̂ᵀ)⁻¹.
/// Row i of X satisfies Lᵀ xᵢᵀ = … — equivalently for each row b of B we
/// solve  x L^T = b  ⇔  L x^T = b^T  (forward substitution per row).
///
/// Blocked: per `CHOL_BLOCK` column panel, an in-place diagonal-block
/// substitution (rows over the pool, no per-row allocation) followed by
/// one packed rank-B update of everything right of the panel.
pub fn solve_xlt_eq_b(l: &Mat, b: &Mat) -> Mat {
    solve_xlt_eq_b_with_threads(l, b, trsm_threads(b.rows, l.rows))
}

/// [`solve_xlt_eq_b`] with an explicit thread count — bit-for-bit
/// identical across thread counts; exposed for determinism tests and
/// tuning.
pub fn solve_xlt_eq_b_with_threads(l: &Mat, b: &Mat, threads: usize) -> Mat {
    let n = l.rows;
    assert_eq!(b.cols, n);
    let rows = b.rows;
    let mut x = b.clone();
    if rows == 0 || n == 0 {
        return x;
    }
    let mut scratch: Vec<f64> = Vec::new();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + CHOL_BLOCK).min(n);
        let bw = k1 - k0;
        // ---- diagonal-block forward substitution, in place
        {
            let ptr = AtomicPtr::new(x.data.as_mut_ptr());
            parallel_ranges(rows, threads, |range| {
                let base = ptr.load(Ordering::Relaxed);
                for r in range {
                    // check-aliasing: row r, columns [k0, k1) is this
                    // task's exclusive write-set
                    crate::util::aliasing::claim(
                        base.wrapping_add(r * n + k0) as *const f64,
                        k1 - k0,
                    );
                    // SAFETY: disjoint row slices per task.
                    let row = unsafe { std::slice::from_raw_parts_mut(base.add(r * n), n) };
                    for i in k0..k1 {
                        let li = l.row(i);
                        let mut s = row[i];
                        for t in k0..i {
                            s -= li[t] * row[t];
                        }
                        row[i] = s / li[i];
                    }
                }
            });
        }
        // ---- deferred rank-bw update of the columns right of the
        // block: X[:, k1..] −= X[:, k0..k1] · L[k1.., k0..k1]ᵀ
        if k1 < n {
            scratch.resize(rows * bw, 0.0);
            for r in 0..rows {
                scratch[r * bw..(r + 1) * bw].copy_from_slice(&x.data[r * n + k0..r * n + k1]);
            }
            // SAFETY: x.data is exclusively borrowed; the updated
            // region (all rows, cols k1..n at stride n) fits inside it
            // and the solved block is read from the scratch copy.
            unsafe {
                crate::linalg::gemm::gemm_nt_acc_ptr(
                    rows,
                    bw,
                    n - k1,
                    &scratch,
                    bw,
                    &l.data[k1 * n + k0..],
                    n,
                    x.data.as_mut_ptr().add(k1),
                    n,
                    -1.0,
                    threads,
                );
            }
        }
        k0 = k1;
    }
    x
}

/// Seed per-row reference for [`solve_xlt_eq_b`] (one forward
/// substitution + one `Vec` per row), kept verbatim for tests and the
/// seed-vs-blocked bench.
pub fn solve_xlt_eq_b_rowwise(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.cols, n);
    let mut x = Mat::zeros(b.rows, n);
    for r in 0..b.rows {
        let sol = solve_lower(l, b.row(r));
        x.row_mut(r).copy_from_slice(&sol);
    }
    x
}

/// A cached Cholesky factorization of an SPD matrix: factor once, then
/// run any number of paired (forward, back) solves against it.  The
/// Alg. 4 Γ-step and the quantizer's `PreparedLayer` front-end hold one
/// of these instead of refactorizing per solve.
pub struct SpdFactor {
    l: Mat,
}

impl SpdFactor {
    pub fn new(a: &Mat) -> Result<SpdFactor> {
        Ok(SpdFactor { l: cholesky(a)? })
    }

    /// The lower-triangular factor L.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve A·x = b through the factor's paired triangular solves
    /// (L·y = b, then Lᵀ·x = y).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_t(&self.l, &y)
    }

    /// log-determinant of A: 2·Σ log ℓ_ii.
    pub fn logdet(&self) -> f64 {
        2.0 * self.l.diag().iter().map(|x| x.ln()).sum::<f64>()
    }
}

/// Inverse of an SPD matrix via Cholesky (used by the Γ-step of Alg. 4:
/// γ = (G + λI)⁻¹ d, solved rather than inverted when possible).
pub fn spd_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    Ok(SpdFactor::new(a)?.solve(b))
}

/// log-determinant of an SPD matrix: 2·Σ log ℓ_ii.
pub fn spd_logdet(a: &Mat) -> Result<f64> {
    Ok(SpdFactor::new(a)?.logdet())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram, matmul};
    use crate::util::rng::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::from_fn(2 * n, n, |_, _| rng.gaussian());
        let mut g = gram(&a).scale(1.0 / (2 * n) as f64);
        g.add_diag(0.05);
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(11);
        for n in [1, 2, 5, 16, 40] {
            let a = spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let re = matmul(&l, &l.transpose());
            assert!(re.sub(&a).max_abs() < 1e-9, "n={n}");
            // lower-triangular with positive diagonal
            for i in 0..n {
                assert!(l[(i, i)] > 0.0);
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1, 3
        assert!(cholesky(&a).is_err());
        assert!(cholesky_unblocked(&a).is_err());
    }

    #[test]
    fn blocked_matches_unblocked_on_block_edge_shapes() {
        // shapes straddling every panel edge of CHOL_BLOCK = 64, plus
        // the acceptance-scale n = 512
        let mut rng = Rng::new(16);
        for n in [1usize, 63, 64, 65, 197, 512] {
            let a = spd(n, &mut rng);
            let l = cholesky_with_threads(&a, 4).unwrap();
            let l0 = cholesky_unblocked(&a).unwrap();
            assert!(
                l.sub(&l0).max_abs() < 1e-9,
                "n={n}: blocked drifted from the reference"
            );
            // lower-triangular with positive diagonal
            for i in 0..n {
                assert!(l[(i, i)] > 0.0, "n={n} pivot {i}");
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], 0.0, "n={n} upper ({i},{j})");
                }
            }
            // and it reconstructs
            let re = matmul(&l, &l.transpose());
            assert!(re.sub(&a).max_abs() < 1e-8, "n={n}");
        }
    }

    #[test]
    fn blocked_cholesky_thread_count_bitwise() {
        // fixed panel/block decomposition ⇒ bit-for-bit equality, not
        // just tolerance, regardless of thread count
        let mut rng = Rng::new(17);
        let a = spd(200, &mut rng);
        let l1 = cholesky_with_threads(&a, 1).unwrap();
        let l8 = cholesky_with_threads(&a, 8).unwrap();
        assert_eq!(l1.data, l8.data, "blocked cholesky must be deterministic");
    }

    #[test]
    fn blocked_trsm_matches_rowwise_on_block_edge_shapes() {
        let mut rng = Rng::new(18);
        for n in [63usize, 64, 65, 197] {
            let a = spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let b = Mat::from_fn(20, n, |_, _| rng.gaussian());
            let x = solve_xlt_eq_b_with_threads(&l, &b, 4);
            let x0 = solve_xlt_eq_b_rowwise(&l, &b);
            assert!(x.sub(&x0).max_abs() < 1e-9, "n={n}");
            // X·Lᵀ = B
            let re = matmul(&x, &l.transpose());
            assert!(re.sub(&b).max_abs() < 1e-8, "n={n}");
        }
    }

    #[test]
    fn blocked_trsm_thread_count_bitwise() {
        let mut rng = Rng::new(19);
        let a = spd(200, &mut rng);
        let l = cholesky(&a).unwrap();
        let b = Mat::from_fn(40, 200, |_, _| rng.gaussian());
        let x1 = solve_xlt_eq_b_with_threads(&l, &b, 1);
        let x8 = solve_xlt_eq_b_with_threads(&l, &b, 8);
        assert_eq!(x1.data, x8.data, "blocked trsm must be deterministic");
    }

    #[test]
    fn solves_are_inverses() {
        let mut rng = Rng::new(12);
        let a = spd(10, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let x = solve_lower(&l, &b);
        // L x = b
        let lx = crate::linalg::gemm::matvec(&l, &x);
        for i in 0..10 {
            assert!((lx[i] - b[i]).abs() < 1e-10);
        }
        let y = solve_lower_t(&l, &b);
        let lty = crate::linalg::gemm::matvec(&l.transpose(), &y);
        for i in 0..10 {
            assert!((lty[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn spd_solve_matches_direct() {
        let mut rng = Rng::new(13);
        let a = spd(8, &mut rng);
        let b: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let x = spd_solve(&a, &b).unwrap();
        let ax = crate::linalg::gemm::matvec(&a, &x);
        for i in 0..8 {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn spd_factor_reuses_across_solves() {
        let mut rng = Rng::new(20);
        let a = spd(12, &mut rng);
        let before = factorization_count();
        let f = SpdFactor::new(&a).unwrap();
        for _ in 0..5 {
            let b: Vec<f64> = (0..12).map(|_| rng.gaussian()).collect();
            let x = f.solve(&b);
            let ax = crate::linalg::gemm::matvec(&a, &x);
            for i in 0..12 {
                assert!((ax[i] - b[i]).abs() < 1e-8);
            }
        }
        // one factorization served all five solve pairs
        assert_eq!(factorization_count() - before, 1);
    }

    #[test]
    fn factorization_counter_increments_per_call() {
        let mut rng = Rng::new(21);
        let a = spd(10, &mut rng);
        let b: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let before = factorization_count();
        let _ = cholesky(&a).unwrap();
        let _ = spd_solve(&a, &b).unwrap();
        let _ = spd_logdet(&a).unwrap();
        assert_eq!(factorization_count() - before, 3);
    }

    #[test]
    fn xlt_solve_matches() {
        let mut rng = Rng::new(14);
        let a = spd(6, &mut rng);
        let l = cholesky(&a).unwrap();
        let b = Mat::from_fn(4, 6, |_, _| rng.gaussian());
        let x = solve_xlt_eq_b(&l, &b);
        let re = matmul(&x, &l.transpose());
        assert!(re.sub(&b).max_abs() < 1e-9);
        // single-block shapes are bit-identical to the seed per-row path
        assert_eq!(x.data, solve_xlt_eq_b_rowwise(&l, &b).data);
    }

    #[test]
    fn logdet_matches_product_of_pivots() {
        let mut rng = Rng::new(15);
        let a = spd(12, &mut rng);
        let ld = spd_logdet(&a).unwrap();
        let l = cholesky(&a).unwrap();
        let direct: f64 = l.diag().iter().map(|x| 2.0 * x.ln()).sum();
        assert!((ld - direct).abs() < 1e-12);
    }
}
