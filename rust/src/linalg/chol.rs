//! Cholesky decomposition and triangular solves — the backbone of ZSIC
//! (Σ = LLᵀ) and of the drift-corrected target ŷ = (WΣ_{X,X̂}+Σ_Δ)(L̂ᵀ)⁻¹.

use anyhow::{bail, Result};

use super::Mat;

/// Lower-triangular Cholesky factor of a PSD matrix: A = L·Lᵀ.
/// Fails if a pivot goes non-positive (caller should damp / erase dead
/// features first — exactly the paper's workflow).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.assert_square()?;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    bail!(
                        "cholesky pivot {i} non-positive ({s:.3e}); \
                         damp or erase dead features"
                    );
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L·x = b with L lower-triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            s -= lrow[k] * x[k];
        }
        x[i] = s / lrow[i];
    }
    x
}

/// Solve Lᵀ·x = b with L lower-triangular (back substitution).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve X·Lᵀ = B row-wise, i.e. X = B·(Lᵀ)⁻¹.  This is the exact
/// operation in eq. (17)/(18): ŷ = (…)·(L̂ᵀ)⁻¹.
/// Row i of X satisfies Lᵀ xᵢᵀ = … — equivalently for each row b of B we
/// solve  x L^T = b  ⇔  L x^T = b^T  (forward substitution per row).
pub fn solve_xlt_eq_b(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.cols, n);
    let mut x = Mat::zeros(b.rows, n);
    for r in 0..b.rows {
        let sol = solve_lower(l, b.row(r));
        x.row_mut(r).copy_from_slice(&sol);
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (used by the Γ-step of Alg. 4:
/// γ = (G + λI)⁻¹ d, solved rather than inverted when possible).
pub fn spd_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Ok(solve_lower_t(&l, &y))
}

/// log-determinant of an SPD matrix: 2·Σ log ℓ_ii.
pub fn spd_logdet(a: &Mat) -> Result<f64> {
    let l = cholesky(a)?;
    Ok(2.0 * l.diag().iter().map(|x| x.ln()).sum::<f64>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram, matmul};
    use crate::util::rng::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::from_fn(2 * n, n, |_, _| rng.gaussian());
        let mut g = gram(&a).scale(1.0 / (2 * n) as f64);
        g.add_diag(0.05);
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(11);
        for n in [1, 2, 5, 16, 40] {
            let a = spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let re = matmul(&l, &l.transpose());
            assert!(re.sub(&a).max_abs() < 1e-9, "n={n}");
            // lower-triangular with positive diagonal
            for i in 0..n {
                assert!(l[(i, i)] > 0.0);
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1, 3
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solves_are_inverses() {
        let mut rng = Rng::new(12);
        let a = spd(10, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let x = solve_lower(&l, &b);
        // L x = b
        let lx = crate::linalg::gemm::matvec(&l, &x);
        for i in 0..10 {
            assert!((lx[i] - b[i]).abs() < 1e-10);
        }
        let y = solve_lower_t(&l, &b);
        let lty = crate::linalg::gemm::matvec(&l.transpose(), &y);
        for i in 0..10 {
            assert!((lty[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn spd_solve_matches_direct() {
        let mut rng = Rng::new(13);
        let a = spd(8, &mut rng);
        let b: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let x = spd_solve(&a, &b).unwrap();
        let ax = crate::linalg::gemm::matvec(&a, &x);
        for i in 0..8 {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn xlt_solve_matches() {
        let mut rng = Rng::new(14);
        let a = spd(6, &mut rng);
        let l = cholesky(&a).unwrap();
        let b = Mat::from_fn(4, 6, |_, _| rng.gaussian());
        let x = solve_xlt_eq_b(&l, &b);
        let re = matmul(&x, &l.transpose());
        assert!(re.sub(&b).max_abs() < 1e-9);
    }

    #[test]
    fn logdet_matches_product_of_pivots() {
        let mut rng = Rng::new(15);
        let a = spd(12, &mut rng);
        let ld = spd_logdet(&a).unwrap();
        let l = cholesky(&a).unwrap();
        let direct: f64 = l.diag().iter().map(|x| 2.0 * x.ln()).sum();
        assert!((ld - direct).abs() < 1e-12);
    }
}
