// Fixture: separate mul + add rounding is the required idiom; the
// words only appearing in comments (mul_add, fma) are not tokens.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}
