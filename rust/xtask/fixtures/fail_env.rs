// Fixture: a direct read of an engine option, plus a literal that
// names no registered knob.
pub fn threads() -> Option<String> {
    std::env::var("WATERSIC_THREADS").ok()
}

pub const TYPO: &str = "WATERSIC_THREDS";
