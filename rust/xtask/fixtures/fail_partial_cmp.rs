// Fixture: partial_cmp + unwrap panics on NaN.
pub fn sort(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
