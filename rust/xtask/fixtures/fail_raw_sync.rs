//! Seeded `no-raw-sync` fixture: the poisoning std primitives used
//! outside util/sync.rs (three import idents + three field types).

use std::sync::{Condvar, Mutex, RwLock};

struct Shared {
    m: Mutex<u32>,
    c: Condvar,
    r: RwLock<u32>,
}
