// Fixture: an unsafe block with no SAFETY comment must be flagged.
pub fn write_one(p: *mut f64) {
    unsafe {
        *p = 1.0;
    }
}
