// Fixture: fused-multiply-add tokens are banned inside linalg/.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s = a[i].mul_add(b[i], s);
    }
    s
}

pub fn uses_intrinsic_name() {
    let _vfmaq_f64 = ();
}
