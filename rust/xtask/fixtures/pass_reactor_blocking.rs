//! Reactor-clean file: readiness waiting with no guard live,
//! non-blocking reads, and properly suppressed off-loop paths.

fn event_loop(stream: &mut TcpStream, poller: &mut Poller) -> io::Result<()> {
    let mut events = Vec::new();
    // no guard is live here: waiting for readiness is the loop's job
    poller.wait(&mut events, None)?;
    let mut buf = [0u8; 4096];
    let _n = stream.read(&mut buf)?;
    stream.set_nonblocking(true)?;
    Ok(())
}

// lint:allow(reactor-blocking) — dedicated per-connection thread, not
// the event loop
fn fallback(stream: &mut TcpStream) -> io::Result<()> {
    let mut line = Vec::new();
    stream.read_to_end(&mut line)?;
    stream.write_all(&line)?;
    std::thread::sleep(Duration::from_millis(1));
    Ok(())
}

fn fault_path() {
    // lint:allow(reactor-blocking) — injected fault: the delay is the point
    std::thread::sleep(Duration::from_millis(1));
}
