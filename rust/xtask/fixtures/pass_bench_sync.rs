//! bench-json-sync pass fixture: every gated entry is emitted into
//! the bench's JSON and (with the paired `pass_bench_sync.yml`)
//! pinned by a CI grep.

const GATED_ENTRIES: &[&str] = &[
    "alpha",
    "beta 128",
];

fn main() {
    let mut log = BenchLog::new("BENCH_ok.json");
    log.meta("bench", Json::Str("ok".to_string()));
    let n = 128;
    let s = Bench::new(&format!("matvec {n}")).run(|| {});
    log.record(&s, None, "packed");
    log.note("alpha", 1.0);
    log.note(&format!("beta {n}"), 2.0);
    if watersic::util::env::flag("WATERSIC_BENCH_ENFORCE") {
        println!("enforcing entries: {}", GATED_ENTRIES.join(", "));
    }
}
