// Fixture: a well-formed suppression — known rule, em-dash (or `--`),
// non-empty reason — silences exactly its rule.
pub fn write_one(p: *mut f64) {
    // lint:allow(unsafe-safety) — fixture demonstrating suppression syntax
    unsafe {
        *p = 1.0;
    }
}

pub fn write_two(p: *mut f64) {
    // lint:allow(unsafe-safety) -- ascii double-dash also accepted
    unsafe {
        *p = 2.0;
    }
}
