//! Seeded `reactor-blocking` fixture: a sleep, synchronous socket I/O,
//! a blocking-mode flip, and a poll wait under a live lock guard —
//! five findings when linted at the reactor path.

use crate::util::sync::{classes, TrackedMutex};

static LOCK: TrackedMutex<u32> = TrackedMutex::new(&classes::SERVE_QUEUE, 0);

fn event_loop(stream: &mut TcpStream, poller: &mut Poller) -> io::Result<()> {
    let mut buf = Vec::new();
    std::thread::sleep(Duration::from_millis(1));
    stream.read_to_end(&mut buf)?;
    stream.write_all(&buf)?;
    stream.set_nonblocking(false)?;
    let g = LOCK.lock();
    poller.wait(&mut buf, None)?;
    drop(g);
    Ok(())
}
