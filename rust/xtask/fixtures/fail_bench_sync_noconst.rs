//! bench-json-sync fail fixture: gates under WATERSIC_BENCH_ENFORCE
//! without declaring GATED_ENTRIES.

fn main() {
    let mut log = BenchLog::new("BENCH_other.json");
    log.note("something", 1.0);
    if watersic::util::env::flag("WATERSIC_BENCH_ENFORCE") {
        std::process::exit(1);
    }
}
