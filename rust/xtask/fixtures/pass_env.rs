// Fixture: registered knob names as plain literals (e.g. handed to
// util::env accessors or set_var in tests) are fine; so are reads of
// non-WATERSIC variables.
pub const KNOB: &str = "WATERSIC_THREADS";

pub fn home() -> Option<String> {
    std::env::var("HOME").ok()
}
