//! Raw-sync-clean file: atomics, channels, and the tracked wrappers
//! are all fine anywhere, and a justified suppression keeps one raw
//! alias.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};

use crate::util::sync::{classes, TrackedCondvar, TrackedMutex};

struct Shared {
    queue: TrackedMutex<Vec<u32>>,
    cv: TrackedCondvar,
    stop: AtomicBool,
}

// lint:allow(no-raw-sync) — FFI boundary: the C side owns this alias
type RawSlot = std::sync::Mutex<u32>;
