//! bench-json-sync fail fixture: one gated entry is never emitted,
//! and the two that are emitted are never grepped by the paired
//! `fail_bench_sync.yml` (which also greps a ghost entry and a JSON
//! nobody writes).

const GATED_ENTRIES: &[&str] = &[
    "present",
    "ungated missing",
    "real 64",
];

fn main() {
    let mut log = BenchLog::new("BENCH_fake.json");
    let n = 64;
    log.note("present", 1.0);
    log.note(&format!("real {n}"), 2.0);
    if watersic::util::env::flag("WATERSIC_BENCH_ENFORCE") {
        println!("enforcing entries: {}", GATED_ENTRIES.join(", "));
    }
}
