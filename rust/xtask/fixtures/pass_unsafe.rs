// Fixture: documented unsafe passes in each accepted shape.
pub fn write_one(p: *mut f64) {
    // SAFETY: caller hands us a valid, exclusive pointer.
    unsafe {
        *p = 1.0;
    }
}

pub fn mid_statement(p: *mut f64, n: usize) -> &'static mut [f64] {
    // SAFETY: the comment sits above the statement, not the `unsafe`
    // token itself — continuation lines are walked through.
    let s =
        unsafe { std::slice::from_raw_parts_mut(p, n) };
    s
}

/// Doc'd contract form.
///
/// # Safety
/// `p` must be valid for writes.
pub unsafe fn write_doc(p: *mut f64) {
    // SAFETY: contract forwarded from the fn's `# Safety` section.
    unsafe {
        *p = 2.0;
    }
}
