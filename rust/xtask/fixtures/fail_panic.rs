// Fixture: all three panic forms on an untrusted surface.
pub fn parse(v: Option<u32>, r: Result<u32, String>) -> u32 {
    let a = v.unwrap();
    let b = r.expect("bad input");
    if a == b {
        panic!("matched");
    }
    a + b
}
