//! Lock-order-clean file: consistent `A` -> `B` nesting everywhere,
//! including through a guard-returning helper.

use crate::util::sync::{classes, TrackedMutex, TrackedMutexGuard};

static A: TrackedMutex<u32> = TrackedMutex::new(&classes::POOL_QUEUE, 0);
static B: TrackedMutex<u32> = TrackedMutex::new(&classes::POOL_JOB, 0);

fn ab() -> u32 {
    let a = A.lock();
    let b = B.lock();
    *a + *b
}

fn also_ab() -> u32 {
    let a = A.lock();
    let b = B.lock();
    *b - *a
}

/// Centralized acquisition: callers inherit the `A` holding.
fn guard_helper() -> TrackedMutexGuard<'static, u32> {
    A.lock()
}

fn uses_guard_helper() -> u32 {
    let g = guard_helper();
    let b = B.lock();
    *g + *b
}
