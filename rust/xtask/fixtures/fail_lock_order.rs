//! Seeded `lock-order` fixture: `A`/`B` invert across two functions,
//! and `C`/`D` invert through a one-level helper call.

use crate::util::sync::{classes, TrackedMutex};

static A: TrackedMutex<u32> = TrackedMutex::new(&classes::POOL_QUEUE, 0);
static B: TrackedMutex<u32> = TrackedMutex::new(&classes::POOL_JOB, 0);
static C: TrackedMutex<u32> = TrackedMutex::new(&classes::FAULT_STATE, 0);
static D: TrackedMutex<u32> = TrackedMutex::new(&classes::ALIASING_TABLES, 0);

fn ab() -> u32 {
    let a = A.lock();
    let b = B.lock();
    *a + *b
}

fn ba() -> u32 {
    let b = B.lock();
    let a = A.lock();
    *a + *b
}

fn helper_locks_c() -> u32 {
    *C.lock()
}

fn holds_d_calls_helper() -> u32 {
    let d = D.lock();
    *d + helper_locks_c()
}

fn holds_c_then_d() -> u32 {
    let c = C.lock();
    let d = D.lock();
    *c + *d
}
