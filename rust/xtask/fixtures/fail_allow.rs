// Fixture: malformed suppressions are themselves findings, and do
// not silence the violation they sit on.
pub fn write_one(p: *mut f64) {
    // lint:allow(no-such-rule) — the rule name is not real
    // lint:allow(unsafe-safety)
    unsafe {
        *p = 1.0;
    }
}
