// Fixture: total_cmp is total — no NaN panic, deterministic order.
pub fn sort(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

pub fn inspect(a: f64, b: f64) -> bool {
    // partial_cmp without the unwrap is fine
    a.partial_cmp(&b).is_some()
}
