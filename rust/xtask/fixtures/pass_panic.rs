// Fixture: untrusted surfaces return errors; tests and reasoned
// suppressions are exempt.
pub fn parse(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "missing field".to_string())
}

pub fn invariant(v: Option<u32>) -> u32 {
    // lint:allow(no-panic-untrusted) — fixture: invariant established above
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::parse(Some(3)).unwrap(), 3);
    }
}
