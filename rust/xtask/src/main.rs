//! watersic-lint: the repo's own static checks, run as
//! `cargo run -p xtask -- lint` (CI blocks on it).
//!
//! Six rule families, tuned to this codebase's pinned invariants (see
//! `rust/xtask/README.md` for the full contract and the suppression
//! syntax):
//!
//! - `unsafe-safety` — every `unsafe` block, fn, or impl carries an
//!   adjacent `// SAFETY:` comment (or a `/// # Safety` doc section).
//! - `no-fma` — no fused-multiply-add tokens (`mul_add`, `fma`,
//!   `vfma`) anywhere in `rust/src/linalg/`: the kernels' bit-for-bit
//!   reproducibility contract requires separate mul + add rounding.
//! - `no-panic-untrusted` — no `.unwrap()` / `.expect(` / `panic!(`
//!   outside `#[cfg(test)]` in the untrusted-input surfaces
//!   (`runtime/server.rs`, `coordinator/container.rs`,
//!   `entropy/rans.rs`): malformed bytes must become `Err`, not a
//!   crashed serving thread.
//! - `no-partial-cmp-unwrap` — `partial_cmp(..).unwrap()` anywhere is
//!   a NaN landmine; `total_cmp` is the house idiom.
//! - `env-registry` — every `WATERSIC_*` engine option is read through
//!   `util::env` (no direct `env::var("WATERSIC_..")` elsewhere),
//!   every such string literal names a registered knob, every
//!   registered knob is documented in `main.rs` USAGE, and every knob
//!   the top-level `README.md` ops section mentions is registered (so
//!   the ops docs cannot drift from the code).
//! - `lint-allow` — suppression comments must name a known rule and
//!   carry an em-dash reason (exact syntax in the README).
//!
//! The analysis is a line-oriented scan over a "code view" of each
//! file (string and comment interiors blanked, positions preserved) —
//! deliberately not a full parser, so it stays dependency-free and
//! fast, at the cost of requiring rustfmt-shaped input (which CI's
//! `cargo fmt --check` already guarantees).

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const KNOWN_RULES: &[&str] = &[
    "unsafe-safety",
    "no-fma",
    "no-panic-untrusted",
    "no-partial-cmp-unwrap",
    "env-registry",
    "lint-allow",
];

/// Files whose inputs arrive from outside the process (wire bytes,
/// container files) — the no-panic rule applies here.
const UNTRUSTED: &[&str] = &[
    "rust/src/runtime/reactor.rs",
    "rust/src/runtime/server.rs",
    "rust/src/coordinator/container.rs",
    "rust/src/entropy/rans.rs",
];

const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "benches", "rust/xtask/src"];

/// Directory names never descended into: vendored stand-in crates and
/// the lint's own deliberately-failing fixture snippets.
const SKIP_DIRS: &[&str] = &["vendor", "fixtures"];

const ENV_REGISTRY_FILE: &str = "rust/src/util/env.rs";
const USAGE_FILE: &str = "rust/src/main.rs";
const README_FILE: &str = "README.md";

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root = PathBuf::from(".");
    let mut cmd: Option<&str> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "lint" => cmd = Some("lint"),
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(d) => root = PathBuf::from(d),
                    None => {
                        eprintln!("xtask: --root needs a directory");
                        return ExitCode::from(2);
                    }
                }
            }
            other => {
                eprintln!("xtask: unknown argument `{other}`");
                eprintln!("usage: cargo run -p xtask -- lint [--root DIR]");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    if cmd != Some("lint") {
        eprintln!("usage: cargo run -p xtask -- lint [--root DIR]");
        return ExitCode::from(2);
    }
    match run_lint(&root) {
        Ok((findings, nfiles)) => {
            for f in &findings {
                println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
            }
            if findings.is_empty() {
                eprintln!("xtask lint: clean ({nfiles} files)");
                ExitCode::SUCCESS
            } else {
                eprintln!("xtask lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::from(2)
        }
    }
}

/// Lint the whole tree under `root`; returns (findings, files seen).
fn run_lint(root: &Path) -> Result<(Vec<Finding>, usize), String> {
    let env_src = fs::read_to_string(root.join(ENV_REGISTRY_FILE))
        .map_err(|e| format!("reading {ENV_REGISTRY_FILE}: {e}"))?;
    let knobs = parse_knobs(&env_src);
    if knobs.is_empty() {
        return Err(format!("no knobs parsed from {ENV_REGISTRY_FILE}"));
    }
    let main_src = fs::read_to_string(root.join(USAGE_FILE))
        .map_err(|e| format!("reading {USAGE_FILE}: {e}"))?;

    let files = collect_files(root);
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path).map_err(|e| format!("reading {rel}: {e}"))?;
        findings.extend(lint_source(&rel, &src, &knobs));
    }
    for name in &knobs {
        if !main_src.contains(name.as_str()) {
            findings.push(Finding {
                file: USAGE_FILE.to_string(),
                line: 1,
                rule: "env-registry",
                msg: format!("registered knob {name} is missing from the USAGE text"),
            });
        }
    }
    // the ops README may only name registered knobs — stale or
    // misspelled docs fail the lint instead of drifting silently
    if let Ok(readme) = fs::read_to_string(root.join(README_FILE)) {
        for (line, name) in doc_knob_mentions(&readme) {
            if !knobs.iter().any(|k| k == &name) {
                findings.push(Finding {
                    file: README_FILE.to_string(),
                    line,
                    rule: "env-registry",
                    msg: format!("{name} is not registered in util::env::KNOBS"),
                });
            }
        }
    }
    findings.sort();
    Ok((findings, files.len()))
}

fn collect_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for r in SCAN_ROOTS {
        let d = root.join(r);
        if d.is_dir() {
            walk(&d, &mut out);
        }
    }
    out.sort();
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if !SKIP_DIRS.contains(&name) {
                walk(&p, out);
            }
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// `WATERSIC_*` knob names mentioned in a prose document, with their
/// 1-based line numbers.  A bare `WATERSIC_` prefix (as in the phrase
/// "any `WATERSIC_*` knob") is not a mention.
fn doc_knob_mentions(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(p) = rest.find("WATERSIC_") {
            let tail = &rest[p..];
            let end = tail
                .find(|c: char| !(c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_'))
                .unwrap_or(tail.len());
            if end > "WATERSIC_".len() {
                out.push((i + 1, tail[..end].to_string()));
            }
            rest = &tail[end..];
        }
    }
    out
}

/// Knob names registered in `util::env::KNOBS` (`name: "..."` fields).
fn parse_knobs(env_src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = env_src;
    while let Some(p) = rest.find("name: \"") {
        let after = &rest[p + 7..];
        if let Some(q) = after.find('"') {
            let name = &after[..q];
            if name.starts_with("WATERSIC_") {
                out.push(name.to_string());
            }
            rest = &after[q..];
        } else {
            break;
        }
    }
    out
}

/// All six rule families over one file.  `rel` is the repo-relative
/// path with `/` separators — it selects which path-scoped rules
/// apply, so tests can exercise fixtures as if they lived anywhere.
fn lint_source(rel: &str, src: &str, knobs: &[String]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let raw_lines: Vec<&str> = src.split('\n').collect();
    let (code, comments) = code_view(src);
    let line_starts = line_starts(src.as_bytes());
    let test_ranges = cfg_test_ranges(&code);
    let supp = Suppressions::parse(src, &comments, &line_starts, rel, &mut findings);

    let finding = |line: usize, rule: &'static str, msg: String| Finding {
        file: rel.to_string(),
        line,
        rule,
        msg,
    };

    let in_linalg = rel.starts_with("rust/src/linalg/");
    let untrusted = UNTRUSTED.contains(&rel);

    for (start, end) in idents(&code) {
        let tok = &code[start..end];
        let line = line_at(&line_starts, start);

        // R1: unsafe-safety
        if tok == b"unsafe" {
            let here = raw_lines.get(line - 1).copied().unwrap_or("");
            let ok = here.contains("SAFETY:")
                || safety_context_above(&raw_lines, line)
                    .iter()
                    .any(|t| t.contains("SAFETY:") || t.contains("# Safety"));
            if !ok && !supp.covers(&raw_lines, "unsafe-safety", line) {
                findings.push(finding(
                    line,
                    "unsafe-safety",
                    "`unsafe` without an adjacent `// SAFETY:` comment or \
                     `/// # Safety` section"
                        .to_string(),
                ));
            }
        }

        // R2: no-fma (linalg only)
        if in_linalg {
            let lower: Vec<u8> = tok.iter().map(|c| c.to_ascii_lowercase()).collect();
            if subslice(tok, b"mul_add") || subslice(&lower, b"fma") {
                if !supp.covers(&raw_lines, "no-fma", line) {
                    findings.push(finding(
                        line,
                        "no-fma",
                        format!(
                            "fused-multiply-add token `{}` in linalg/ breaks the \
                             separate-rounding reproducibility contract",
                            String::from_utf8_lossy(tok)
                        ),
                    ));
                }
            }
        }

        // R3: no-panic-untrusted
        if untrusted && !in_ranges(&test_ranges, start) {
            let hit = match tok {
                b"unwrap" => {
                    prev_nonws(&code, start) == Some(b'.') && call_is_empty(&code, end)
                }
                b"expect" => {
                    prev_nonws(&code, start) == Some(b'.')
                        && next_nonws(&code, end) == Some(b'(')
                }
                b"panic" => {
                    next_nonws(&code, end) == Some(b'!')
                        // `panic!` then `(`: skip the `!` and any ws
                        && next_nonws(&code, skip_to(&code, end, b'!') + 1) == Some(b'(')
                }
                _ => false,
            };
            if hit && !supp.covers(&raw_lines, "no-panic-untrusted", line) {
                findings.push(finding(
                    line,
                    "no-panic-untrusted",
                    format!(
                        "`{}` on an untrusted-input surface — return Err or \
                         suppress with a reason",
                        String::from_utf8_lossy(tok)
                    ),
                ));
            }
        }

        // R4: no-partial-cmp-unwrap (everywhere)
        if tok == b"partial_cmp" {
            if let Some(after) = balanced_call_end(&code, end) {
                let mut tail = Vec::with_capacity(12);
                let mut j = after;
                while j < code.len() && tail.len() < 12 {
                    if !code[j].is_ascii_whitespace() {
                        tail.push(code[j]);
                    }
                    j += 1;
                }
                if tail.starts_with(b".unwrap()") || tail.starts_with(b".expect(") {
                    if !supp.covers(&raw_lines, "no-partial-cmp-unwrap", line) {
                        findings.push(finding(
                            line,
                            "no-partial-cmp-unwrap",
                            "`partial_cmp(..).unwrap()` panics on NaN — use \
                             `total_cmp`"
                                .to_string(),
                        ));
                    }
                }
            }
        }
    }

    // R5a: direct env reads of engine options outside the registry
    if rel != ENV_REGISTRY_FILE {
        let bytes = src.as_bytes();
        for pos in find_all(&code, b"env::var") {
            // the literal itself lives in the raw bytes (the code view
            // blanks string interiors but preserves every position)
            let mut j = pos + 8;
            if bytes.get(j..j + 3) == Some(&b"_os"[..]) {
                j += 3;
            }
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if bytes.get(j) != Some(&b'(') {
                continue;
            }
            j += 1;
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if bytes.get(j) == Some(&b'"') && bytes[j + 1..].starts_with(b"WATERSIC_") {
                let line = line_at(&line_starts, pos);
                if !supp.covers(&raw_lines, "env-registry", line) {
                    findings.push(finding(
                        line,
                        "env-registry",
                        "direct env read of a WATERSIC_* option — go through \
                         util::env"
                            .to_string(),
                    ));
                }
            }
        }
        // R5b: every quoted WATERSIC_* literal must be a registered knob
        for (pos, name) in watersic_literals(src) {
            if !knobs.iter().any(|k| k == &name) {
                let line = line_at(&line_starts, pos);
                if !supp.covers(&raw_lines, "env-registry", line) {
                    findings.push(finding(
                        line,
                        "env-registry",
                        format!("{name} is not registered in util::env::KNOBS"),
                    ));
                }
            }
        }
    }

    findings
}

// ---- suppressions -------------------------------------------------

struct Suppressions {
    by_line: HashMap<usize, Vec<&'static str>>,
}

impl Suppressions {
    /// Parse suppression comments — the marker, a known rule name in
    /// parens, then an em-dash (or `--`) and a reason; malformed ones
    /// become `lint-allow` findings.  Only true comment spans are
    /// scanned, so the marker inside a string literal is inert.
    fn parse(
        src: &str,
        comments: &[(usize, usize)],
        starts: &[usize],
        rel: &str,
        findings: &mut Vec<Finding>,
    ) -> Suppressions {
        let mut by_line: HashMap<usize, Vec<&'static str>> = HashMap::new();
        for &(cs, ce) in comments {
            let c = &src[cs..ce];
            let Some(q) = c.find("lint:allow(") else { continue };
            let ln = line_at(starts, cs + q);
            let after = &c[q + "lint:allow(".len()..];
            let Some(r) = after.find(')') else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: "unclosed lint:allow(".to_string(),
                });
                continue;
            };
            let rule = after[..r].trim();
            let Some(&known) = KNOWN_RULES.iter().find(|k| **k == rule) else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: format!("unknown rule `{rule}` in lint:allow"),
                });
                continue;
            };
            let rest = after[r + 1..].trim_start();
            let reason = rest
                .strip_prefix('—')
                .or_else(|| rest.strip_prefix("--"))
                .or_else(|| rest.strip_prefix('-'))
                .map(str::trim)
                .unwrap_or("");
            if reason.is_empty() {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: format!(
                        "suppression needs a reason: `// lint:allow({rule}) — why`"
                    ),
                });
                continue;
            }
            by_line.entry(ln).or_default().push(known);
        }
        Suppressions { by_line }
    }

    /// A violation on `line` is covered by an allow on that line or in
    /// the contiguous comment block immediately above it.
    fn covers(&self, raw_lines: &[&str], rule: &'static str, line: usize) -> bool {
        let at = |ln: usize| self.by_line.get(&ln).is_some_and(|v| v.contains(&rule));
        if at(line) {
            return true;
        }
        let mut i = line - 1;
        while i >= 1 {
            let t = raw_lines.get(i - 1).map(|s| s.trim()).unwrap_or("");
            if t.starts_with("//") {
                if at(i) {
                    return true;
                }
                i -= 1;
            } else {
                break;
            }
        }
        false
    }
}

/// Lines to search for a SAFETY comment above `line`: contiguous
/// comments, attribute lines, and statement continuations (a previous
/// line that doesn't end in `;`/`{`/`}` means `line` belongs to the
/// same statement, so keep walking up to the statement's own comment).
fn safety_context_above<'a>(raw_lines: &[&'a str], line: usize) -> Vec<&'a str> {
    let mut texts = Vec::new();
    let mut i = line - 1;
    while i >= 1 {
        let t = raw_lines.get(i - 1).map(|s| s.trim()).unwrap_or("");
        if t.starts_with("//") {
            texts.push(t);
            i -= 1;
        } else if t.starts_with("#[") || t.starts_with("#![") {
            i -= 1;
        } else if !t.is_empty() && !t.ends_with([';', '{', '}']) {
            i -= 1;
        } else {
            break;
        }
    }
    texts
}

// ---- code view ----------------------------------------------------

/// Copy of the source with comment bodies and string/char interiors
/// blanked to spaces (newlines kept), so token scans can't match text,
/// plus the byte spans of the comments themselves — suppressions are
/// parsed from those spans only, so the marker appearing inside a
/// string literal is inert.
fn code_view(src: &str) -> (Vec<u8>, Vec<(usize, usize)>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments = Vec::new();
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let mut j = i;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                blank(&mut out, i, j);
                comments.push((i, j));
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i, j);
                comments.push((i, j));
                i = j;
            }
            b'r' if !ident_before(b, i) && raw_string_start(b, i).is_some() => {
                i = blank_raw_string(b, &mut out, i);
            }
            b'b' if !ident_before(b, i) && i + 1 < n && b[i + 1] == b'"' => {
                i = blank_plain_string(b, &mut out, i + 1);
            }
            b'b' if !ident_before(b, i)
                && i + 1 < n
                && b[i + 1] == b'r'
                && raw_string_start(b, i + 1).is_some() =>
            {
                i = blank_raw_string(b, &mut out, i + 1);
            }
            b'"' => {
                i = blank_plain_string(b, &mut out, i);
            }
            b'\'' => {
                i = blank_char_or_lifetime(b, &mut out, i);
            }
            _ => i += 1,
        }
    }
    (out, comments)
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for c in out[from.min(out.len())..to.min(out.len())].iter_mut() {
        if *c != b'\n' {
            *c = b' ';
        }
    }
}

fn ident_before(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1] == b'_' || b[i - 1].is_ascii_alphanumeric())
}

/// `Some(hash_count)` if `b[i..]` opens a raw string `r#*"`.
fn raw_string_start(b: &[u8], i: usize) -> Option<usize> {
    if b.get(i) != Some(&b'r') {
        return None;
    }
    let mut j = i + 1;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    (b.get(j) == Some(&b'"')).then_some(j - i - 1)
}

/// Blank `"..."` starting at the quote `at`; returns the index after.
fn blank_plain_string(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    let mut j = at + 1;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => break,
            _ => j += 1,
        }
    }
    blank(out, at + 1, j.min(n));
    (j + 1).min(n)
}

/// Blank `r#"..."#` whose `r` is at `at`; returns the index after.
fn blank_raw_string(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    let hashes = raw_string_start(b, at).unwrap_or(0);
    let body = at + 1 + hashes + 1;
    let mut j = body;
    while j < n {
        if b[j] == b'"' && b[j + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes
        {
            blank(out, body, j);
            return j + 1 + hashes;
        }
        j += 1;
    }
    blank(out, body, n);
    n
}

/// Blank a char literal at `at`, or step over a lifetime tick.
fn blank_char_or_lifetime(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    if at + 1 >= n {
        return at + 1;
    }
    if b[at + 1] == b'\\' {
        // escaped char literal: blank to the closing quote
        let mut j = at + 2;
        while j < n && b[j] != b'\'' {
            j += 1;
        }
        blank(out, at + 1, j.min(n));
        return (j + 1).min(n);
    }
    // single-char literal `'x'` (possibly multi-byte UTF-8); anything
    // else — `'a` in generics, `&'static` — is a lifetime: skip it
    let ch_len = utf8_len(b[at + 1]);
    if at + 1 + ch_len < n && b[at + 1 + ch_len] == b'\'' && b[at + 1] != b'\'' {
        blank(out, at + 1, at + 1 + ch_len);
        at + 2 + ch_len
    } else {
        at + 1
    }
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---- scanning helpers ---------------------------------------------

/// Byte offsets where each line starts (index 0 = line 1).
fn line_starts(b: &[u8]) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            v.push(i + 1);
        }
    }
    v
}

fn line_at(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

/// `(start, end)` of every identifier token in the code view.
fn idents(code: &[u8]) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        let c = code[i];
        if c == b'_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < n && (code[i] == b'_' || code[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            v.push((s, i));
        } else if c.is_ascii_digit() {
            // numeric literal (incl. a suffix like `0usize`): not an
            // ident — but stop at `.` so `x.0.unwrap()` still yields
            // the `unwrap` token
            while i < n && (code[i] == b'_' || code[i].is_ascii_alphanumeric()) {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    v
}

fn subslice(hay: &[u8], needle: &[u8]) -> bool {
    hay.windows(needle.len()).any(|w| w == needle)
}

fn find_all(hay: &[u8], needle: &[u8]) -> Vec<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return Vec::new();
    }
    (0..=hay.len() - needle.len())
        .filter(|&i| &hay[i..i + needle.len()] == needle)
        .collect()
}

fn prev_nonws(code: &[u8], mut i: usize) -> Option<u8> {
    while i > 0 {
        i -= 1;
        if !code[i].is_ascii_whitespace() {
            return Some(code[i]);
        }
    }
    None
}

fn next_nonws(code: &[u8], mut i: usize) -> Option<u8> {
    while i < code.len() {
        if !code[i].is_ascii_whitespace() {
            return Some(code[i]);
        }
        i += 1;
    }
    None
}

/// First index at or after `i` holding `what` (or `code.len()`).
fn skip_to(code: &[u8], mut i: usize, what: u8) -> usize {
    while i < code.len() && code[i] != what {
        i += 1;
    }
    i
}

/// `.unwrap()` check: after the ident, `(` then `)` with only ws.
fn call_is_empty(code: &[u8], end: usize) -> bool {
    let open = skip_to(code, end, b'(');
    if next_nonws(code, end) != Some(b'(') {
        return false;
    }
    next_nonws(code, open + 1) == Some(b')')
}

/// Index just past the balanced `(...)` that follows `end`, if any.
fn balanced_call_end(code: &[u8], end: usize) -> Option<usize> {
    if next_nonws(code, end) != Some(b'(') {
        return None;
    }
    let open = skip_to(code, end, b'(');
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < code.len() && depth > 0 {
        match code[j] {
            b'(' => depth += 1,
            b')' => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    (depth == 0).then_some(j)
}

/// Byte ranges of `#[cfg(test)]` items (attribute through closing
/// brace) in the code view.
fn cfg_test_ranges(code: &[u8]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    for m in find_all(code, b"#[cfg(test)]") {
        let mut k = m + b"#[cfg(test)]".len();
        // opening brace of the following item (a `;` first means the
        // attribute decorated a brace-less item: nothing to span)
        let mut open = None;
        while k < code.len() {
            match code[k] {
                b'{' => {
                    open = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        let Some(open) = open else { continue };
        let mut depth = 1usize;
        let mut j = open + 1;
        while j < code.len() && depth > 0 {
            match code[j] {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        ranges.push((m, j));
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], pos: usize) -> bool {
    ranges.iter().any(|&(a, b)| pos >= a && pos < b)
}

/// `(offset, name)` of every quoted `"WATERSIC_..."` literal.
fn watersic_literals(src: &str) -> Vec<(usize, String)> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    for pos in find_all(b, b"\"WATERSIC_") {
        let start = pos + 1;
        let mut j = start;
        while j < b.len() && (b[j] == b'_' || b[j].is_ascii_uppercase() || b[j].is_ascii_digit())
        {
            j += 1;
        }
        // require a non-empty suffix and the closing quote so prefix
        // constants like `"WATERSIC_"` don't register as knob names
        if j > start + "WATERSIC_".len() && b.get(j) == Some(&b'"') {
            out.push((pos, String::from_utf8_lossy(&b[start..j]).to_string()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const KNOBS: &[&str] = &["WATERSIC_THREADS", "WATERSIC_LOG"];

    fn lint(rel: &str, src: &str) -> Vec<Finding> {
        let knobs: Vec<String> = KNOBS.iter().map(|s| s.to_string()).collect();
        lint_source(rel, src, &knobs)
    }

    fn rules(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn unsafe_rule_fires_and_passes() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_unsafe.rs"));
        assert!(rules(&f).contains(&"unsafe-safety"), "{f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_unsafe.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fma_rule_scoped_to_linalg() {
        let src = include_str!("../fixtures/fail_fma.rs");
        let f = lint("rust/src/linalg/x.rs", src);
        assert!(rules(&f).contains(&"no-fma"), "{f:?}");
        // the same tokens outside linalg/ are fine
        let f = lint("rust/src/model/x.rs", src);
        assert!(!rules(&f).contains(&"no-fma"), "{f:?}");
        let f = lint(
            "rust/src/linalg/x.rs",
            include_str!("../fixtures/pass_fma.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn panic_rule_scoped_to_untrusted_surfaces() {
        let src = include_str!("../fixtures/fail_panic.rs");
        let f = lint("rust/src/runtime/server.rs", src);
        let n = rules(&f)
            .iter()
            .filter(|r| **r == "no-panic-untrusted")
            .count();
        assert_eq!(n, 3, "unwrap + expect + panic! should all fire: {f:?}");
        // not an untrusted surface -> no findings
        let f = lint("rust/src/eval/mod.rs", src);
        assert!(!rules(&f).contains(&"no-panic-untrusted"), "{f:?}");
        let f = lint(
            "rust/src/runtime/server.rs",
            include_str!("../fixtures/pass_panic.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn partial_cmp_rule_fires_everywhere() {
        let f = lint(
            "rust/src/model/x.rs",
            include_str!("../fixtures/fail_partial_cmp.rs"),
        );
        assert!(rules(&f).contains(&"no-partial-cmp-unwrap"), "{f:?}");
        let f = lint(
            "rust/src/model/x.rs",
            include_str!("../fixtures/pass_partial_cmp.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn env_rule_catches_direct_reads_and_unknown_knobs() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_env.rs"));
        let n = rules(&f).iter().filter(|r| **r == "env-registry").count();
        assert_eq!(n, 2, "direct read + unregistered literal: {f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_env.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn readme_knob_mentions_tokenize_and_skip_bare_prefixes() {
        let text = "set `WATERSIC_SERVE_QUEUE=64` (or any `WATERSIC_*` knob)\n\
                    WATERSIC_FAULT='read=partial'";
        let got = doc_knob_mentions(text);
        let want = vec![
            (1, "WATERSIC_SERVE_QUEUE".to_string()),
            (2, "WATERSIC_FAULT".to_string()),
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn suppressions_cover_and_malformed_ones_fail() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_allow.rs"));
        assert!(f.is_empty(), "{f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_allow.rs"));
        let n = rules(&f).iter().filter(|r| **r == "lint-allow").count();
        assert_eq!(n, 2, "unknown rule + missing reason: {f:?}");
        // a malformed allow does NOT suppress the violation under it
        assert!(rules(&f).contains(&"unsafe-safety"), "{f:?}");
    }

    #[test]
    fn code_view_blanks_strings_and_comments() {
        let src = "let s = \"unsafe .unwrap()\"; // unsafe here too\n";
        let (code, comments) = code_view(src);
        assert!(!subslice(&code, b"unwrap"));
        assert!(!subslice(&code, b"unsafe"));
        // positions and line structure survive; the line comment span
        // is reported
        assert_eq!(code.len(), src.len());
        assert_eq!(comments.len(), 1);
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        let f = lint("rust/src/runtime/server.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    /// The real tree must be clean — the same invariant CI enforces
    /// with `cargo run -p xtask -- lint`.
    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../.."));
        let (findings, nfiles) = run_lint(root).expect("lint run");
        assert!(findings.is_empty(), "{findings:#?}");
        assert!(nfiles > 20, "scanned only {nfiles} files");
    }
}
